//! Integration tests across solvers and the coreset: the paper's central
//! empirical claim — training on the coreset ≈ training on the full data
//! — plus exact-solver cross-checks (greedy vs DP vs coreset estimates).

use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::datasets;
use sigtree::rng::Rng;
use sigtree::segmentation::dp2d::TreeDP;
use sigtree::segmentation::greedy::greedy_tree;
use sigtree::signal::{generate, PrefixStats};
use sigtree::tree::forest::{ForestParams, RandomForest};
use sigtree::tree::gbdt::{Gbdt, GbdtParams};
use sigtree::tree::{DecisionTree, Sample, TreeParams};

/// Train-on-coreset ≈ train-on-full for single CART trees (the Fig. 5–7
/// appendix claim, numeric version).
#[test]
fn tree_on_coreset_close_to_tree_on_full() {
    let mut rng = Rng::new(31);
    let (sig, _) = generate::piecewise_constant(96, 96, 8, 0.2, &mut rng);
    let full_samples = datasets::signal_to_samples(&sig);
    let cs = SignalCoreset::construct(&sig, 16, 0.25);
    let cs_samples: Vec<Sample> = cs.weighted_points().iter().map(Sample::from_point).collect();
    assert!(
        cs_samples.len() * 3 < full_samples.len(),
        "coreset must compress ({} vs {})",
        cs_samples.len(),
        full_samples.len()
    );
    let params = TreeParams::default().with_max_leaves(16);
    let t_full = DecisionTree::fit(&full_samples, &params, None);
    let t_core = DecisionTree::fit(&cs_samples, &params, None);
    // Compare both trees' SSE on the full data.
    let sse_full = t_full.sse(&full_samples);
    let sse_core = t_core.sse(&full_samples);
    let whole_var = PrefixStats::new(&sig).opt1(&sig.bounds());
    assert!(
        sse_core <= sse_full + 0.15 * whole_var,
        "coreset-trained tree SSE {sse_core} vs full-trained {sse_full} (var {whole_var})"
    );
}

/// The pipeline the paper actually proposes: run the *expensive exact DP*
/// on the coreset-compressed signal. We verify the DP-on-coreset chooses
/// a segmentation whose true loss is near the DP-on-full optimum.
#[test]
fn exact_dp_on_coreset_approximates_optimum() {
    let mut rng = Rng::new(37);
    let (sig, _) = generate::piecewise_constant(20, 20, 4, 0.05, &mut rng);
    let stats = PrefixStats::new(&sig);
    let k = 4;
    let opt = TreeDP::new(&stats).opt(sig.bounds(), k);
    // Coreset route: evaluate the greedy candidates through the coreset
    // and pick the best (a solver that never touches the full data).
    let cs = SignalCoreset::construct(&sig, k, 0.2);
    let candidates: Vec<_> = (2..=8)
        .map(|kk| greedy_tree(&stats, kk))
        .collect();
    let best_by_coreset = candidates
        .iter()
        .min_by(|a, b| {
            cs.fitting_loss(a)
                .partial_cmp(&cs.fitting_loss(b))
                .unwrap()
        })
        .unwrap();
    let true_loss = best_by_coreset.loss(&stats);
    let whole = stats.opt1(&sig.bounds());
    assert!(
        true_loss <= opt + 0.1 * whole + 1e-9,
        "coreset-selected loss {true_loss} vs opt {opt}"
    );
}

#[test]
fn forest_and_gbdt_on_coreset_generalize() {
    let mut rng = Rng::new(41);
    let sig = datasets::air_quality_like(0.05, &mut rng);
    let (masked, held) = datasets::holdout_patches(&sig, 0.3, 5, &mut rng);
    let full_samples = datasets::signal_to_samples(&masked);
    let cs = SignalCoreset::construct(&masked, 300, 0.3);
    let cs_samples: Vec<Sample> = cs.weighted_points().iter().map(Sample::from_point).collect();

    let fp = ForestParams::default().with_trees(8).with_max_leaves(64);
    let f_full = RandomForest::fit(&full_samples, &fp, &mut rng);
    let f_core = RandomForest::fit(&cs_samples, &fp, &mut rng);
    let sse = |f: &RandomForest| -> f64 {
        held.iter()
            .map(|&(r, c, y)| (f.predict(&[r as f64, c as f64]) - y).powi(2))
            .sum()
    };
    let (s_full, s_core) = (sse(&f_full), sse(&f_core));
    // "similar accuracy": within 3× on this noisy task at 5% dataset
    // scale (the paper reports a 0.03 SSE gap on normalized data at full
    // scale with k=2000; bench_fig4 reproduces that regime — this test
    // only guards against qualitative regression).
    assert!(
        s_core <= 3.0 * s_full,
        "forest on coreset {s_core} vs full {s_full}"
    );

    let gp = GbdtParams::default().with_stages(15).with_leaves(16);
    let g_core = Gbdt::fit(&cs_samples, &gp, &mut rng);
    let g_sse: f64 = held
        .iter()
        .map(|&(r, c, y)| (g_core.predict(&[r as f64, c as f64]) - y).powi(2))
        .sum();
    assert!(
        g_sse.is_finite() && g_sse <= 5.0 * s_full.max(1.0),
        "gbdt {g_sse} vs forest-on-full {s_full}"
    );
}

/// Rasterized point datasets (Figs. 5–7) flow through the whole system.
#[test]
fn rasterized_blobs_coreset_and_tree() {
    let mut rng = Rng::new(43);
    let pts = datasets::blobs(0.1, &mut rng);
    let sig = datasets::rasterize(&pts, 64, 64);
    let cs = SignalCoreset::construct(&sig, 32, 0.3);
    assert!(cs.stored_points() > 0);
    assert!((cs.total_weight() - sig.present() as f64).abs() < 1e-6 * sig.present() as f64);
    let samples: Vec<Sample> = cs.weighted_points().iter().map(Sample::from_point).collect();
    let tree = DecisionTree::fit(
        &samples,
        &TreeParams::default().with_max_leaves(16),
        None,
    );
    // The 3 blob labels (0, 1, 2) should be predicted within broad bands.
    let preds: Vec<f64> = (0..64)
        .flat_map(|r| (0..64).map(move |c| (r, c)))
        .filter(|&(r, c)| sig.is_present(r, c))
        .map(|(r, c)| tree.predict(&[r as f64, c as f64]))
        .collect();
    let spread = preds.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - preds.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread > 0.5, "tree collapsed to a constant (spread {spread})");
}

#[test]
fn prop_greedy_tree_never_below_dp() {
    sigtree::proptest::check("greedy>=dp", 5, |rng| {
        let sig = generate::noise(8 + rng.usize(4), 8 + rng.usize(4), 1.0, rng);
        let stats = PrefixStats::new(&sig);
        let k = 2 + rng.usize(3);
        let g = greedy_tree(&stats, k).loss(&stats);
        let o = TreeDP::new(&stats).opt(sig.bounds(), k);
        if g < o - 1e-9 {
            return Err(format!("greedy {g} below optimal {o}"));
        }
        Ok(())
    });
}
