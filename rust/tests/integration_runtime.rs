//! Integration tests for the kernel-runtime path: backend → execute →
//! parity with the native f64 implementation. They run against every
//! backend this build offers: the pure-Rust [`NativeBackend`] always,
//! plus the PJRT backend when compiled with `--features pjrt` and the
//! AOT artifacts load (skipped with a log line otherwise).

use sigtree::rng::Rng;
use sigtree::runtime::{pad_integral, KernelBackend, NativeBackend, TILE};
use sigtree::signal::{generate, PrefixStats, Rect};

#[cfg(feature = "pjrt")]
fn pjrt_backend() -> Option<Box<dyn KernelBackend>> {
    if !sigtree::runtime::artifacts_available() {
        eprintln!("skipping pjrt backend: artifacts not built (run `make artifacts`)");
        return None;
    }
    match sigtree::runtime::pjrt::Runtime::load_default() {
        Ok(rt) => Some(Box::new(rt)),
        Err(e) => {
            eprintln!("skipping pjrt backend: {e}");
            None
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend() -> Option<Box<dyn KernelBackend>> {
    None
}

/// Every backend available in this build (native is unconditional).
fn backends() -> Vec<Box<dyn KernelBackend>> {
    let mut v: Vec<Box<dyn KernelBackend>> = vec![Box::new(NativeBackend::new())];
    if let Some(rt) = pjrt_backend() {
        v.push(rt);
    }
    v
}

#[test]
fn native_backend_is_always_available() {
    let names: Vec<String> = backends().iter().map(|b| b.name()).collect();
    assert!(names.iter().any(|n| n == "native"), "{names:?}");
}

#[test]
fn full_tile_roundtrip_matches_native_f64() {
    for backend in backends() {
        let mut rng = Rng::new(123);
        let sig = generate::image_like(TILE, TILE, 4, &mut rng);
        let tile: Vec<f32> = sig.values().iter().map(|&v| v as f32).collect();
        let (ii_y, ii_y2) = backend.prefix2d(&tile).unwrap();
        let p_y = pad_integral(&ii_y);
        let p_y2 = pad_integral(&ii_y2);
        let stats = PrefixStats::new(&sig);
        // Batch of structured rects: rows, columns, squares, full tile.
        let mut rects = Vec::new();
        for i in 0..32 {
            let a = i * 8;
            rects.push([a as i32, a as i32, 0, (TILE - 1) as i32]); // row
            rects.push([0, (TILE - 1) as i32, a as i32, a as i32]); // col
            rects.push([a as i32, (a + 7) as i32, a as i32, (a + 7) as i32]); // square
        }
        rects.push([0, (TILE - 1) as i32, 0, (TILE - 1) as i32]);
        let got = backend.block_sse(&p_y, &p_y2, &rects).unwrap();
        for (g, r) in got.iter().zip(rects.iter()) {
            let rect = Rect::new(r[0] as usize, r[1] as usize, r[2] as usize, r[3] as usize);
            let e = stats.opt1(&rect);
            assert!(
                (*g as f64 - e).abs() <= 0.05 * (1.0 + e),
                "backend {}, rect {rect:?}: kernel {g} vs native {e}",
                backend.name()
            );
        }
    }
}

#[test]
fn seg_loss_kernel_evaluates_segmentations() {
    for backend in backends() {
        let mut rng = Rng::new(321);
        let sig = generate::smooth(TILE, TILE, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let mut seg = sigtree::segmentation::random_segmentation(sig.bounds(), 12, &mut rng);
        seg.refit_values(&stats);
        let rendered = seg.render(TILE, TILE);
        let a: Vec<f32> = sig.values().iter().map(|&v| v as f32).collect();
        let b: Vec<f32> = rendered.values().iter().map(|&v| v as f32).collect();
        let got = backend.seg_loss(&a, &b).unwrap() as f64;
        let exact = seg.loss(&stats);
        assert!(
            (got - exact).abs() <= 1e-2 * (1.0 + exact),
            "backend {}: kernel {got} vs native {exact}",
            backend.name()
        );
    }
}

#[test]
fn backend_is_reusable_across_many_calls() {
    // The compile-once property: repeated execution must not re-compile
    // (smoke: repeated calls complete quickly and agree with each other).
    for backend in backends() {
        let tile = vec![1.0f32; TILE * TILE];
        let (first, _) = backend.prefix2d(&tile).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            let (again, _) = backend.prefix2d(&tile).unwrap();
            assert_eq!(again[TILE * TILE - 1], first[TILE * TILE - 1]);
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "backend {}: 10 executions took {:?} — looks like recompilation per call",
            backend.name(),
            t0.elapsed()
        );
    }
}

#[test]
fn backend_from_name_cli_contract() {
    // The CLI's `--backend` switch: native always resolves; pjrt either
    // resolves (feature + artifacts) or returns a descriptive error.
    let native = sigtree::runtime::backend_from_name("native", None).unwrap();
    assert_eq!(native.name(), "native");
    match sigtree::runtime::backend_from_name("pjrt", None) {
        Ok(b) => assert!(b.name().starts_with("pjrt")),
        Err(e) => {
            let msg = e.to_string();
            assert!(
                msg.contains("pjrt") || msg.contains("artifacts"),
                "unhelpful error: {msg}"
            );
        }
    }
    assert!(sigtree::runtime::backend_from_name("bogus", None).is_err());
}
