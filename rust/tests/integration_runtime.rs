//! Integration tests for the PJRT runtime path: artifacts → compile →
//! execute → parity with the native f64 implementation. All tests skip
//! gracefully (with a log line) when `make artifacts` has not run.

use sigtree::rng::Rng;
use sigtree::runtime::{artifacts_available, pad_integral, Runtime, TILE};
use sigtree::signal::{generate, PrefixStats, Rect};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_available() {
        eprintln!("skipping runtime integration: artifacts not built");
        return None;
    }
    Some(Runtime::load_default().expect("runtime load"))
}

#[test]
fn all_three_artifacts_load_and_list() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.artifact_names();
    for expected in ["block_sse", "prefix2d", "seg_loss"] {
        assert!(names.iter().any(|n| n == expected), "{expected} missing from {names:?}");
    }
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
}

#[test]
fn full_tile_roundtrip_matches_native_f64() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(123);
    let sig = generate::image_like(TILE, TILE, 4, &mut rng);
    let tile: Vec<f32> = sig.values().iter().map(|&v| v as f32).collect();
    let (ii_y, ii_y2) = rt.prefix2d(&tile).unwrap();
    let p_y = pad_integral(&ii_y);
    let p_y2 = pad_integral(&ii_y2);
    let stats = PrefixStats::new(&sig);
    // Batch of structured rects: rows, columns, squares, full tile.
    let mut rects = Vec::new();
    for i in 0..32 {
        let a = i * 8;
        rects.push([a as i32, a as i32, 0, (TILE - 1) as i32]); // row
        rects.push([0, (TILE - 1) as i32, a as i32, a as i32]); // col
        rects.push([a as i32, (a + 7) as i32, a as i32, (a + 7) as i32]); // square
    }
    rects.push([0, (TILE - 1) as i32, 0, (TILE - 1) as i32]);
    let got = rt.block_sse(&p_y, &p_y2, &rects).unwrap();
    for (g, r) in got.iter().zip(rects.iter()) {
        let rect = Rect::new(r[0] as usize, r[1] as usize, r[2] as usize, r[3] as usize);
        let e = stats.opt1(&rect);
        assert!(
            (*g as f64 - e).abs() <= 0.05 * (1.0 + e),
            "rect {rect:?}: pjrt {g} vs native {e}"
        );
    }
}

#[test]
fn seg_loss_artifact_evaluates_segmentations() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(321);
    let sig = generate::smooth(TILE, TILE, 3, &mut rng);
    let stats = PrefixStats::new(&sig);
    let mut seg = sigtree::segmentation::random_segmentation(sig.bounds(), 12, &mut rng);
    seg.refit_values(&stats);
    let rendered = seg.render(TILE, TILE);
    let a: Vec<f32> = sig.values().iter().map(|&v| v as f32).collect();
    let b: Vec<f32> = rendered.values().iter().map(|&v| v as f32).collect();
    let got = rt.seg_loss(&a, &b).unwrap() as f64;
    let exact = seg.loss(&stats);
    assert!(
        (got - exact).abs() <= 1e-2 * (1.0 + exact),
        "pjrt {got} vs native {exact}"
    );
}

#[test]
fn runtime_is_reusable_across_many_calls() {
    // The compile-once property: repeated execution must not re-compile
    // (smoke: 50 calls complete quickly and agree with each other).
    let Some(rt) = runtime_or_skip() else { return };
    let tile = vec![1.0f32; TILE * TILE];
    let (first, _) = rt.prefix2d(&tile).unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..10 {
        let (again, _) = rt.prefix2d(&tile).unwrap();
        assert_eq!(again[TILE * TILE - 1], first[TILE * TILE - 1]);
    }
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "10 executions took {:?} — looks like recompilation per call",
        t0.elapsed()
    );
}
