//! View/crop differential suite — the zero-copy acceptance tests.
//!
//! A [`SignalView`] presents the same cells in the same order as the
//! equivalent [`Signal::crop`], so every generic consumer must produce
//! **bit-identical** results over either; and the shared-stats shard
//! path (`build_in` / `build_par`) must be thread-count-invariant at
//! 1/2/4/8 workers. Quality-level equivalences (shared global stats vs
//! per-band local stats) are tested with tolerances where bit-equality
//! is not mathematically guaranteed.

use sigtree::coreset::merge_reduce::StreamingCoreset;
use sigtree::coreset::{Coreset, CoresetConfig, SignalCoreset};
use sigtree::rng::Rng;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Rect, Signal, SignalSource};

/// Assert two coresets are bitwise equal (blocks, labels, weights).
fn assert_bit_identical(a: &SignalCoreset, b: &SignalCoreset, ctx: &str) {
    assert_eq!(a.blocks.len(), b.blocks.len(), "{ctx}: block count");
    for (x, y) in a.blocks.iter().zip(&b.blocks) {
        assert_eq!(x.rect, y.rect, "{ctx}");
        assert_eq!(x.labels, y.labels, "{ctx}");
        assert_eq!(x.weights, y.weights, "{ctx}");
    }
}

/// Build over a view vs over the equivalent crop: bit-identical.
fn assert_view_crop_identical(sig: &Signal, window: Rect, k: usize, eps: f64, ctx: &str) {
    let from_view = SignalCoreset::construct(&sig.view(window), k, eps);
    let from_crop = SignalCoreset::construct(&sig.crop(window), k, eps);
    assert_bit_identical(&from_view, &from_crop, ctx);
    assert_eq!(from_view.rows(), window.height(), "{ctx}");
    assert_eq!(from_view.cols(), window.width(), "{ctx}");
}

#[test]
fn view_vs_crop_aligned_signal() {
    // Window height a multiple of the 64-row shard granularity.
    let mut rng = Rng::new(400);
    let sig = generate::smooth(160, 48, 3, &mut rng);
    assert_view_crop_identical(&sig, Rect::new(16, 143, 0, 47), 4, 0.3, "aligned");
}

#[test]
fn view_vs_crop_ragged_signal() {
    let mut rng = Rng::new(401);
    let sig = generate::image_like(150, 41, 3, &mut rng);
    assert_view_crop_identical(&sig, Rect::new(7, 129, 3, 37), 5, 0.25, "ragged");
}

#[test]
fn view_vs_crop_masked_signal() {
    let mut rng = Rng::new(402);
    let mut sig = generate::smooth(120, 40, 3, &mut rng);
    sig.mask_rect(Rect::new(30, 70, 5, 20));
    sig.mask_rect(Rect::new(0, 10, 0, 39)); // window edge fully masked
    assert_view_crop_identical(&sig, Rect::new(0, 99, 0, 39), 4, 0.3, "masked");
}

#[test]
fn build_par_over_view_vs_crop_at_many_thread_counts() {
    // The sharded builder is generic too: for every thread count the
    // view build equals the crop build bit-for-bit, and all thread
    // counts agree with each other.
    let mut rng = Rng::new(403);
    let sig = generate::smooth(300, 36, 3, &mut rng);
    let window = Rect::new(10, 279, 0, 35); // 270 rows → 4 shards
    let config = CoresetConfig::new(4, 0.3);
    let crop = sig.crop(window);
    let reference = SignalCoreset::construct_sharded(&crop, config, 1);
    for threads in [1, 2, 4, 8] {
        let from_view = SignalCoreset::construct_sharded(&sig.view(window), config, threads);
        let from_crop = SignalCoreset::construct_sharded(&crop, config, threads);
        assert_bit_identical(&from_view, &from_crop, &format!("threads {threads}"));
        assert_bit_identical(&from_view, &reference, &format!("threads {threads} vs 1T"));
    }
}

#[test]
fn shared_stats_shard_build_covers_its_region() {
    // `build_in` against one global PrefixStats: blocks tile exactly the
    // band, in global coordinates, with the band's exact present weight.
    let mut rng = Rng::new(404);
    let mut sig = generate::smooth(200, 32, 3, &mut rng);
    sig.mask_rect(Rect::new(80, 95, 4, 20));
    let stats = PrefixStats::new(&sig);
    let config = CoresetConfig::new(4, 0.3);
    let band = Rect::new(64, 159, 0, 31);
    let part = SignalCoreset::construct_in(&sig, &stats, band, config);
    assert_eq!(part.rows(), band.height());
    assert_eq!(part.cols(), band.width());
    let mut present = 0.0;
    for (r, c) in band.cells() {
        if sig.is_present(r, c) {
            present += 1.0;
        }
    }
    assert!(
        (part.total_weight() - present).abs() <= 1e-6 * (1.0 + present),
        "weight {} vs present {present}",
        part.total_weight()
    );
    for b in &part.blocks {
        assert!(band.contains_rect(&b.rect), "block {:?} outside band", b.rect);
    }
    // Full-bounds build_in degenerates to the monolithic build exactly.
    let whole = SignalCoreset::construct_in(&sig, &stats, sig.bounds(), config);
    let mono = SignalCoreset::construct_with_stats(&sig, &stats, config);
    assert_bit_identical(&whole, &mono, "full-bounds build_in");
}

#[test]
fn streaming_views_equal_streaming_crops_bitwise() {
    // push_band is generic: feeding views and feeding crops of the same
    // bands must stream the identical coreset.
    let mut rng = Rng::new(405);
    let mut sig = generate::smooth(256, 24, 3, &mut rng);
    sig.mask_rect(Rect::new(100, 140, 0, 11));
    let config = CoresetConfig::new(3, 0.3);
    let mut by_view = StreamingCoreset::new(24, config);
    let mut by_crop = StreamingCoreset::new(24, config);
    let mut r0 = 0;
    while r0 < 256 {
        let r1 = (r0 + 63).min(255);
        let band = Rect::new(r0, r1, 0, 23);
        by_view.push_band(&sig.view(band));
        by_crop.push_band(&sig.crop(band));
        r0 = r1 + 1;
    }
    let a = by_view.finish().unwrap();
    let b = by_crop.finish().unwrap();
    assert_bit_identical(&a, &b, "streamed views vs crops");
}

#[test]
fn shared_stats_build_par_quality_matches_monolithic() {
    // The zero-copy shard path must keep the coreset contract: exact
    // weight, and fitting losses within tolerance of the exact oracle.
    let mut rng = Rng::new(406);
    let sig = generate::smooth(320, 64, 4, &mut rng);
    let stats = PrefixStats::new(&sig);
    let config = CoresetConfig::new(6, 0.25);
    let cs = SignalCoreset::construct_sharded(&sig, config, 0);
    let cells = (320 * 64) as f64;
    assert!((cs.total_weight() - cells).abs() <= 1e-6 * cells);
    for _ in 0..15 {
        let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        let approx = cs.fitting_loss(&s);
        assert!(
            (approx - exact).abs() <= 0.35 * exact + 1e-6,
            "{approx} vs {exact}"
        );
    }
}

#[test]
fn masked_audit_case_family_over_views() {
    // The guarantee audit's masked case family, run through the zero-copy
    // path: stats and coreset built over a *view* of a masked signal must
    // produce the exact same audit sweep (per-query losses and empirical
    // errors) as the owned crop, masked cells must contribute zero to
    // both sides, and every gated family stays within ε.
    use sigtree::audit::build_queries;
    use sigtree::coreset::fitting_loss::relative_error;

    let mut rng = Rng::new(408);
    let mut sig = generate::smooth(80, 42, 3, &mut rng);
    generate::random_mask(&mut sig, 0.15, &mut rng);
    sig.mask_rect(Rect::new(30, 37, 0, 41)); // a fully-masked band
    let window = Rect::new(4, 71, 2, 39);
    let eps = 0.5;
    let k = 4;

    let view = sig.view(window);
    let crop = sig.crop(window);
    let stats_view = PrefixStats::new(&view);
    let stats_crop = PrefixStats::new(&crop);
    let cs_view = SignalCoreset::construct(&view, k, eps);
    let cs_crop = SignalCoreset::construct(&crop, k, eps);
    assert_bit_identical(&cs_view, &cs_crop, "masked audit coreset");

    // One query sweep, evaluated against both builds: identical losses
    // (bit-identical inputs) and every gated family within its threshold.
    let mut qrng = Rng::new(409);
    let (families, queries) =
        build_queries(crop.bounds(), &stats_view, &cs_view, None, k, false, &mut qrng);
    let via_view = cs_view.fitting_loss_batch(&queries, 1);
    let via_crop = cs_crop.fitting_loss_batch(&queries, 2);
    assert_eq!(via_view, via_crop, "view and crop evaluations must agree");
    for ((family, q), approx) in families.iter().zip(&queries).zip(via_view) {
        let exact_view = q.loss(&stats_view);
        let exact_crop = q.loss(&stats_crop);
        assert_eq!(exact_view, exact_crop);
        let err = relative_error(approx, exact_view);
        let threshold = family.threshold(eps).expect("masked sweep families are gated");
        assert!(
            err <= threshold,
            "family {} err {err} > {threshold} on masked view",
            family.name()
        );
    }

    // The fully-masked band contributes nothing: its true loss is exactly
    // zero for any query value, and the coreset stores no block (hence no
    // weight) inside it — only the documented boundary-straddle smoothing
    // can charge a query there (DESIGN.md §Masks).
    let dead_local = Rect::new(30 - window.r0, 37 - window.r0, 0, crop.cols() - 1);
    let dead_query = sigtree::segmentation::KSegmentation::constant(dead_local, 42.0);
    assert_eq!(dead_query.loss(&stats_view), 0.0);
    for b in &cs_view.blocks {
        assert!(
            !dead_local.contains_rect(&b.rect),
            "zero-weight block stored inside the masked band: {:?}",
            b.rect
        );
    }
}

#[test]
fn samplers_build_bit_identical_over_views_and_crops() {
    // The sampling family is generic over SignalSource like the
    // deterministic builders: a seeded sample of a view must equal the
    // sample of the equivalent crop bit-for-bit, for every algorithm,
    // and the uniform baseline sampler follows the same contract.
    use sigtree::coreset::uniform::UniformSample;
    use sigtree::sample::{SampleAlgorithm, SampleParams, SensitivityCoreset};

    let mut rng = Rng::new(410);
    let mut sig = generate::smooth(90, 40, 3, &mut rng);
    sig.mask_rect(Rect::new(20, 33, 5, 17));
    let window = Rect::new(6, 77, 2, 37);
    let view = sig.view(window);
    let crop = sig.crop(window);

    let params = SampleParams::new(4, 0.3, 120, 23);
    for algorithm in SampleAlgorithm::ALL {
        let from_view = SensitivityCoreset::build(&view, algorithm, &params);
        let from_crop = SensitivityCoreset::build(&crop, algorithm, &params);
        assert_eq!(from_view, from_crop, "{} view vs crop", algorithm.name());
        assert_eq!(from_view.rows(), window.height());
        assert_eq!(from_view.cols(), window.width());
    }

    let from_view = UniformSample::build(&view, 80, &mut Rng::new(24));
    let from_crop = UniformSample::build(&crop, 80, &mut Rng::new(24));
    assert_eq!(from_view, from_crop, "uniform sampler view vs crop");
}

#[test]
fn nested_views_build_like_their_flat_equivalent() {
    // view(view(rect)) composes offsets against the root signal, so a
    // nested window builds the same coreset as the flat window.
    let mut rng = Rng::new(407);
    let sig = generate::image_like(140, 30, 3, &mut rng);
    let outer = sig.view(Rect::new(10, 129, 2, 27));
    let inner = outer.view(Rect::new(5, 104, 1, 24));
    let flat = sig.view(Rect::new(15, 114, 3, 26));
    let a = SignalCoreset::construct(&inner, 4, 0.3);
    let b = SignalCoreset::construct(&flat, 4, 0.3);
    assert_bit_identical(&a, &b, "nested vs flat view");
}
