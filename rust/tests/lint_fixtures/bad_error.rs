//! Fixture: error discipline — a `Result<_, String>` public API.

pub fn load(text: &str) -> Result<u32, String> {
    text.parse::<u32>().map_err(|e| e.to_string())
}
