//! Fixture: opt-in `index-hot` — indexing in a deterministic module.

pub fn pick(v: &[u32], i: usize) -> u32 {
    v[i]
}
