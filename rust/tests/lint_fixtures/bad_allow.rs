//! Fixture: malformed, unknown-rule, and dangling allow directives.

pub fn unknown(v: Option<u32>) -> u32 {
    // lint:allow(bogus-rule) -- not a rule id
    v.unwrap()
}

pub fn missing_reason(v: Option<u32>) -> u32 {
    // lint:allow(panic)
    v.unwrap()
}

pub fn dangling() -> u32 {
    // lint:allow(panic) -- suppresses nothing
    7
}
