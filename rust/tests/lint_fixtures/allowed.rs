//! Fixture: correctly waived matches — the linter reports nothing.

pub fn waived(v: Option<u32>) -> u32 {
    // lint:allow(panic) -- fixture invariant: always Some
    v.unwrap()
}

pub fn same_line(v: Option<u32>) -> u32 {
    v.unwrap() // lint:allow(panic) -- fixture invariant: always Some
}
