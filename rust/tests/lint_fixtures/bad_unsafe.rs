//! Fixture: `unsafe` with and without a SAFETY justification.

pub fn covered(x: *const u32) -> u32 {
    // SAFETY: caller guarantees `x` is valid (fixture).
    unsafe { *x }
}

pub fn uncovered(x: *const u32) -> u32 {
    unsafe { *x }
}
