//! Fixture: a deprecated `build*` shim that no longer delegates.

pub struct Thing;

impl Thing {
    pub fn construct() -> Self {
        Thing
    }

    #[deprecated(note = "use construct")]
    pub fn build() -> Self {
        Thing
    }
}
