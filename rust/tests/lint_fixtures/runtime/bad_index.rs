//! Fixture: `index-hot` — per-element indexing on a hot kernel path.

pub fn pick(v: &[u32], i: usize) -> u32 {
    v[i]
}
