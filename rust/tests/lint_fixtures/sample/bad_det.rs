//! Fixture: determinism violations inside the sampling module path.

use std::collections::HashMap;

pub fn order() -> usize {
    let m: HashMap<u32, u32> = HashMap::new();
    m.len()
}

pub fn clock() -> bool {
    std::time::Instant::now().elapsed().as_nanos() % 2 == 0
}

pub fn threads() {
    std::thread::spawn(|| {}).join().ok();
}
