//! Fixture: `#[cfg(test)]` items are exempt from every rule.

pub fn live() -> u32 {
    7
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        assert_eq!(super::live(), 7);
        Some(1u32).unwrap();
        panic!("fine in tests");
    }
}
