//! Fixture: panic-freedom violations (see `integration_lint`).

pub fn first(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn second(v: Option<u32>) -> u32 {
    v.expect("fixture")
}

pub fn third() {
    panic!("fixture");
}
