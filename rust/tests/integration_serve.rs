//! End-to-end tests of the `sigtree serve` daemon over real loopback
//! sockets: batched-vs-sequential bit-identity under concurrent
//! clients, coreset-cache behavior, network-input hardening, and the
//! `POST /shutdown` drain.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;

use sigtree::engine::{Engine, EngineConfig};
use sigtree::json::Json;
use sigtree::segmentation::KSegmentation;
use sigtree::serve::{http, ServeConfig, Server};
use sigtree::signal::{Rect, Signal};

fn engine_config() -> EngineConfig {
    let mut cfg = EngineConfig::new(4, 0.4);
    cfg.threads = 2;
    cfg
}

fn test_signal() -> Signal {
    Signal::from_fn(32, 24, |r, c| ((3 * r + 5 * c) % 11) as f64 * 0.37 - 1.0)
}

fn signal_json(signal: &Signal) -> Json {
    let mut values = Vec::with_capacity(signal.len());
    for r in 0..signal.rows() {
        for c in 0..signal.cols() {
            values.push(Json::num(signal.get(r, c)));
        }
    }
    Json::obj(vec![
        ("rows", Json::int(signal.rows())),
        ("cols", Json::int(signal.cols())),
        ("values", Json::Arr(values)),
    ])
}

/// Horizontal-stripe segmentation parameterised by `salt`, produced
/// both as the wire JSON and as the in-process [`KSegmentation`] so
/// the test evaluates the *same* query locally and over the socket.
fn stripes(rows: usize, cols: usize, pieces: usize, salt: usize) -> (Json, KSegmentation) {
    let mut json_pieces = Vec::new();
    let mut seg_pieces = Vec::new();
    let step = rows / pieces;
    for i in 0..pieces {
        let r0 = i * step;
        let r1 = if i + 1 == pieces { rows - 1 } else { (i + 1) * step - 1 };
        // Awkward, non-round values so bit-identity is a real check.
        let value = (salt as f64 + 1.0) * 0.1 + i as f64 / 3.0 - 0.7;
        json_pieces.push(Json::obj(vec![
            ("r0", Json::int(r0)),
            ("r1", Json::int(r1)),
            ("c0", Json::int(0)),
            ("c1", Json::int(cols - 1)),
            ("value", Json::num(value)),
        ]));
        seg_pieces.push((Rect { r0, r1, c0: 0, c1: cols - 1 }, value));
    }
    (
        Json::obj(vec![("pieces", Json::Arr(json_pieces))]),
        KSegmentation::new(seg_pieces),
    )
}

fn start_server(
    serve_threads: usize,
    batch_window_ms: u64,
) -> (SocketAddr, thread::JoinHandle<()>) {
    let engine = Engine::new(engine_config()).expect("engine");
    let cfg = ServeConfig {
        threads: serve_threads,
        batch_window_ms,
        ..ServeConfig::default()
    };
    let server = Server::bind(engine, cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    let handle = thread::spawn(move || server.run().expect("serve run"));
    (addr, handle)
}

fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("send");
    stream.flush().expect("flush");
    let mut reader = BufReader::new(stream);
    http::read_response(&mut reader).expect("response")
}

fn shutdown(addr: SocketAddr, handle: thread::JoinHandle<()>) {
    let (status, body) = request(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200, "{body}");
    let doc = Json::parse(&body).expect("shutdown json");
    assert_eq!(doc.get("draining").and_then(Json::as_bool), Some(true));
    handle.join().expect("server thread");
}

fn losses_of(body: &str) -> Vec<f64> {
    let doc = Json::parse(body).expect("response json");
    let Some(Json::Arr(raw)) = doc.get("losses") else {
        panic!("no losses in {body}");
    };
    raw.iter().map(|l| l.as_f64().expect("loss number")).collect()
}

/// The tentpole guarantee: funnelling many concurrent clients through
/// the batching collector returns, per query, the exact bits sequential
/// evaluation produces — at every server thread count.
#[test]
fn batched_fitting_loss_is_bit_identical_to_sequential_across_thread_counts() {
    let signal = test_signal();
    let sig_json = signal_json(&signal);

    // Sequential reference: same engine config, one query per call.
    let engine = Engine::new(engine_config()).expect("engine");
    let coreset = engine.coreset(&signal);

    const CLIENTS: usize = 6;
    const QUERIES_PER_CLIENT: usize = 8;
    let mut expected: Vec<Vec<f64>> = Vec::new();
    let mut wire_queries: Vec<Vec<Json>> = Vec::new();
    for client in 0..CLIENTS {
        let mut exp = Vec::new();
        let mut wire = Vec::new();
        for q in 0..QUERIES_PER_CLIENT {
            let salt = client * QUERIES_PER_CLIENT + q;
            let (json, seg) = stripes(signal.rows(), signal.cols(), 2 + salt % 3, salt);
            exp.push(engine.fitting_loss(&coreset, std::slice::from_ref(&seg))[0]);
            wire.push(json);
        }
        expected.push(exp);
        wire_queries.push(wire);
    }

    for server_threads in [1usize, 2, 4, 8] {
        // A generous window so concurrent requests actually coalesce.
        let (addr, handle) = start_server(server_threads, 20);
        let sig_json = sig_json.clone();
        let bodies: Vec<String> = wire_queries
            .iter()
            .map(|qs| {
                Json::obj(vec![
                    ("signal", sig_json.clone()),
                    ("queries", Json::Arr(qs.clone())),
                ])
                .render()
            })
            .collect();
        let bodies = Arc::new(bodies);

        let mut clients = Vec::new();
        for i in 0..CLIENTS {
            let bodies = Arc::clone(&bodies);
            clients.push(thread::spawn(move || {
                let (status, body) = request(addr, "POST", "/fitting_loss", &bodies[i]);
                assert_eq!(status, 200, "client {i}: {body}");
                losses_of(&body)
            }));
        }
        for (i, client) in clients.into_iter().enumerate() {
            let got = client.join().expect("client thread");
            assert_eq!(got.len(), QUERIES_PER_CLIENT);
            for (q, (&g, &e)) in got.iter().zip(&expected[i]).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    e.to_bits(),
                    "threads={server_threads} client={i} query={q}: got {g}, expected {e}"
                );
            }
        }

        // The batching machinery ran (batches is also bumped by
        // unbatched singleton groups, so this only asserts liveness).
        let (status, body) = request(addr, "GET", "/stats", "");
        assert_eq!(status, 200);
        let stats = Json::parse(&body).expect("stats json");
        assert!(stats.get("batches").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0, "{body}");
        assert_eq!(
            stats
                .get("queries")
                .and_then(Json::as_usize),
            Some(CLIENTS * QUERIES_PER_CLIENT),
            "{body}"
        );
        shutdown(addr, handle);
    }
}

#[test]
fn coreset_cache_hits_misses_and_digest_addressing() {
    let (addr, handle) = start_server(2, 0);
    let signal = test_signal();
    let body = Json::obj(vec![("signal", signal_json(&signal))]).render();

    // First build: a miss.
    let (status, resp) = request(addr, "POST", "/coreset", &body);
    assert_eq!(status, 200, "{resp}");
    let doc = Json::parse(&resp).expect("json");
    assert_eq!(doc.get("cached").and_then(Json::as_bool), Some(false), "{resp}");
    let digest = doc.get("digest").and_then(Json::as_str).expect("digest").to_string();

    // Same signal again: a rebuild-free hit.
    let (status, resp) = request(addr, "POST", "/coreset", &body);
    assert_eq!(status, 200);
    let doc = Json::parse(&resp).expect("json");
    assert_eq!(doc.get("cached").and_then(Json::as_bool), Some(true), "{resp}");

    // Digest-only addressing skips re-uploading the signal entirely.
    let (query, seg) = stripes(signal.rows(), signal.cols(), 3, 1);
    let fit_body = Json::obj(vec![
        ("digest", Json::str(digest.clone())),
        ("queries", Json::Arr(vec![query])),
    ])
    .render();
    let (status, resp) = request(addr, "POST", "/fitting_loss", &fit_body);
    assert_eq!(status, 200, "{resp}");
    let engine = Engine::new(engine_config()).expect("engine");
    let coreset = engine.coreset(&signal);
    let expected = engine.fitting_loss(&coreset, std::slice::from_ref(&seg))[0];
    assert_eq!(losses_of(&resp)[0].to_bits(), expected.to_bits());

    // Stats agree: one build, several hits, entry count 1.
    let (status, resp) = request(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    let stats = Json::parse(&resp).expect("stats json");
    let cache = stats.get("cache").expect("cache section");
    assert_eq!(cache.get("entries").and_then(Json::as_usize), Some(1), "{resp}");
    assert!(cache.get("hits").and_then(Json::as_f64).unwrap_or(0.0) >= 2.0, "{resp}");
    assert!(cache.get("misses").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0, "{resp}");
    assert_eq!(stats.get("coreset_builds").and_then(Json::as_usize), Some(1), "{resp}");

    // Unknown digest: 404, not a silent rebuild.
    let miss_body = Json::obj(vec![
        ("digest", Json::str("0xdeadbeef")),
        ("queries", Json::Arr(vec![])),
    ])
    .render();
    let (status, resp) = request(addr, "POST", "/fitting_loss", &miss_body);
    assert_eq!(status, 404, "{resp}");

    shutdown(addr, handle);
}

#[test]
fn hostile_input_is_rejected_with_4xx_not_a_crash() {
    let (addr, handle) = start_server(2, 0);

    // Malformed JSON body.
    let (status, resp) = request(addr, "POST", "/coreset", "{not json");
    assert_eq!(status, 400, "{resp}");

    // Valid JSON, invalid shape.
    let (status, resp) = request(addr, "POST", "/coreset", "{\"rows\": 3}");
    assert_eq!(status, 400, "{resp}");

    // Overlapping query rectangles must be rejected, not asserted on.
    let signal = test_signal();
    let overlap = Json::obj(vec![
        ("signal", signal_json(&signal)),
        (
            "queries",
            Json::Arr(vec![Json::obj(vec![(
                "pieces",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("r0", Json::int(0)),
                        ("r1", Json::int(10)),
                        ("c0", Json::int(0)),
                        ("c1", Json::int(10)),
                        ("value", Json::num(1.0)),
                    ]),
                    Json::obj(vec![
                        ("r0", Json::int(5)),
                        ("r1", Json::int(15)),
                        ("c0", Json::int(5)),
                        ("c1", Json::int(15)),
                        ("value", Json::num(2.0)),
                    ]),
                ]),
            )])]),
        ),
    ])
    .render();
    let (status, resp) = request(addr, "POST", "/fitting_loss", &overlap);
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("overlap"), "{resp}");

    // Oversized Content-Length is refused from the header alone.
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(
        stream,
        "POST /coreset HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n"
    )
    .expect("send");
    stream.flush().expect("flush");
    let mut reader = BufReader::new(stream);
    let (status, _) = http::read_response(&mut reader).expect("response");
    assert_eq!(status, 413);

    // Garbage request line.
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(b"TOTAL GARBAGE\r\n\r\n").expect("send");
    let mut reader = BufReader::new(stream);
    let (status, _) = http::read_response(&mut reader).expect("response");
    assert_eq!(status, 400);

    // Unknown endpoint / wrong method.
    let (status, _) = request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    let (status, _) = request(addr, "GET", "/coreset", "");
    assert_eq!(status, 405);

    // The daemon survived all of the above.
    let (status, resp) = request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert!(resp.contains("true"), "{resp}");

    shutdown(addr, handle);
}

#[test]
fn keep_alive_serves_multiple_requests_on_one_connection() {
    let (addr, handle) = start_server(1, 0);
    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    for _ in 0..3 {
        write!(stream, "GET /healthz HTTP/1.1\r\nContent-Length: 0\r\n\r\n").expect("send");
        stream.flush().expect("flush");
        let (status, body) = http::read_response(&mut reader).expect("response");
        assert_eq!(status, 200, "{body}");
    }
    drop(stream);
    shutdown(addr, handle);
}

#[test]
fn shutdown_drains_and_releases_the_port() {
    let (addr, handle) = start_server(4, 5);

    // Some traffic first so the drain has state to wind down.
    let signal = test_signal();
    let (q, _) = stripes(signal.rows(), signal.cols(), 2, 0);
    let body = Json::obj(vec![
        ("signal", signal_json(&signal)),
        ("queries", Json::Arr(vec![q])),
    ])
    .render();
    let (status, resp) = request(addr, "POST", "/fitting_loss", &body);
    assert_eq!(status, 200, "{resp}");

    // The drain request itself gets a well-formed 200 before teardown,
    // and run() returns (asserted inside `shutdown` via join).
    shutdown(addr, handle);

    // The listener is gone: a fresh connect must fail (the dummy
    // wake-up socket may linger in the backlog, so allow a beat).
    let mut refused = false;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Err(_) => {
                refused = true;
                break;
            }
            Ok(stream) => {
                drop(stream);
                thread::sleep(std::time::Duration::from_millis(10));
            }
        }
    }
    assert!(refused, "port still accepting after drain");
}
