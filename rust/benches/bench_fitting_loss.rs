//! FITTING-LOSS benchmarks: the Definition 3 / Theorem 8 validation (E9)
//! plus evaluation throughput (Algorithm 5 is O(k·|C|); the whole point
//! of a coreset is that this beats the O(N) exact evaluation).

use sigtree::benchkit::{bench, fmt_duration, fmt_f, Table};
use sigtree::coreset::fitting_loss::relative_error;
use sigtree::coreset::uniform::UniformSample;
use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::rng::Rng;
use sigtree::segmentation::{greedy::greedy_tree, random_segmentation};
use sigtree::signal::{generate, PrefixStats};
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(9);
    let sig = generate::image_like(512, 512, 4, &mut rng);
    let stats = PrefixStats::new(&sig);

    // --- E9: empirical ε over query ensembles, per ε setting. ---
    let k = 32;
    let mut table = Table::new(&[
        "eps",
        "size %",
        "mean err (random)",
        "worst err (random)",
        "err (greedy tree)",
        "worst err (uniform)",
    ]);
    for eps in [0.4, 0.2, 0.1] {
        let cs = SignalCoreset::construct(&sig, k, eps);
        let us = UniformSample::build(&sig, cs.size(), &mut rng);
        let mut worst = 0.0f64;
        let mut mean = 0.0f64;
        let mut uworst = 0.0f64;
        let queries = 100;
        for _ in 0..queries {
            let mut s = random_segmentation(sig.bounds(), k, &mut rng);
            s.refit_values(&stats);
            let exact = s.loss(&stats);
            let err = relative_error(cs.fitting_loss(&s), exact);
            worst = worst.max(err);
            mean += err;
            uworst = uworst.max(relative_error(us.fitting_loss(&s), exact));
        }
        mean /= queries as f64;
        let gt = greedy_tree(&stats, k);
        let gerr = relative_error(cs.fitting_loss(&gt), gt.loss(&stats));
        table.row(&[
            eps.to_string(),
            format!("{:.2}", 100.0 * cs.compression_ratio()),
            fmt_f(mean),
            fmt_f(worst),
            fmt_f(gerr),
            fmt_f(uworst),
        ]);
    }
    table.print("E9: empirical approximation error (Definition 3 validation)");

    // --- Evaluation throughput: coreset vs exact-on-full-data. ---
    let cs = SignalCoreset::construct(&sig, k, 0.2);
    let queries: Vec<_> = (0..50)
        .map(|_| {
            let mut s = random_segmentation(sig.bounds(), k, &mut rng);
            s.refit_values(&stats);
            s
        })
        .collect();
    let t_core = bench(1, 10, Duration::from_secs(4), || {
        queries.iter().map(|s| cs.fitting_loss(s)).sum::<f64>()
    });
    let t_exact_prefix = bench(1, 10, Duration::from_secs(4), || {
        queries.iter().map(|s| s.loss(&stats)).sum::<f64>()
    });
    let t_exact_naive = bench(1, 3, Duration::from_secs(6), || {
        queries
            .iter()
            .map(|s| s.loss_bruteforce(&sig))
            .sum::<f64>()
    });
    let mut table = Table::new(&["evaluator", "50 queries", "evals/s"]);
    for (name, t) in [
        ("FITTING-LOSS (coreset)", t_core),
        ("exact via prefix stats", t_exact_prefix),
        ("exact naive O(N)", t_exact_naive),
    ] {
        table.row(&[
            name.into(),
            fmt_duration(t.median),
            fmt_f(50.0 / t.median.as_secs_f64()),
        ]);
    }
    table.print("Algorithm 5 evaluation throughput (N=262k, k=32)");
}
