//! Construction benchmarks: the §4 size claim (E8) and the O(kN) time
//! claim (E10).
//!
//! * `size_claim` — N ≈ 140,000 (374×374), k = 1000, ε = 0.2: the paper
//!   reports an empirical coreset ≤ 1% of the input where the worst-case
//!   bound exceeds N.
//! * `scaling`    — build time vs N (fixed k) and vs k (fixed N): both
//!   should be ~linear (the O(kN) bound; in practice the k-dependence is
//!   sublinear because only the bicriteria stage scales with k).

use sigtree::benchkit::{bench, fmt_duration, fmt_f, Table};
use sigtree::coreset::SignalCoreset;
use sigtree::rng::Rng;
use sigtree::signal::generate;
use std::time::Duration;

fn main() {
    // --- E8: the §4 empirical-size claim. ---
    // Workload: the air-quality-like matrix at full scale — 9358×15 =
    // 140,370 ≈ the paper's N ~ 140,000 (its N comes from these tabular
    // datasets, not from square images).
    let mut rng = Rng::new(4);
    let sig = sigtree::datasets::air_quality_like(1.0, &mut rng);
    let _n = sig.rows();
    let k = 1000;
    let eps = 0.2;
    let t = bench(0, 3, Duration::from_secs(10), || {
        SignalCoreset::construct(&sig, k, eps)
    });
    let cs = SignalCoreset::construct(&sig, k, eps);
    let mut table = Table::new(&["N", "k", "eps", "coreset pts", "% of N", "build time"]);
    table.row(&[
        sig.len().to_string(),
        k.to_string(),
        eps.to_string(),
        cs.stored_points().to_string(),
        format!("{:.2}", 100.0 * cs.compression_ratio()),
        fmt_duration(t.median),
    ]);
    table.print("E8 / §4 size claim (paper: ≤1% at N≈140k, k=1000, ε=0.2)");

    // --- E10: linear scaling in N. ---
    let mut table = Table::new(&["N", "build (median)", "cells/s"]);
    for side in [128usize, 256, 512, 724] {
        let mut rng = Rng::new(7);
        let sig = generate::image_like(side, side, 4, &mut rng);
        let t = bench(1, 5, Duration::from_secs(6), || {
            SignalCoreset::construct(&sig, 64, 0.2)
        });
        table.row(&[
            (side * side).to_string(),
            fmt_duration(t.median),
            fmt_f((side * side) as f64 / t.median.as_secs_f64()),
        ]);
    }
    table.print("E10a: build time vs N (k=64) — cells/s should stay ~flat");

    // --- E10b: scaling in k. ---
    let mut rng = Rng::new(8);
    let sig = generate::image_like(384, 384, 4, &mut rng);
    let mut table = Table::new(&["k", "build (median)"]);
    for k in [8usize, 64, 512, 2000] {
        let t = bench(1, 5, Duration::from_secs(6), || {
            SignalCoreset::construct(&sig, k, 0.2)
        });
        table.row(&[k.to_string(), fmt_duration(t.median)]);
    }
    table.print("E10b: build time vs k (N=147k)");
}
