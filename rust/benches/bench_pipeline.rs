//! Pipeline benchmarks: streaming-coordinator throughput and the effect
//! of band size / worker count / backpressure (the L3 ablations DESIGN.md
//! calls out).

use sigtree::benchkit::{bench, fmt_duration, fmt_f, Table};
use sigtree::coreset::CoresetConfig;
use sigtree::pipeline::{run, PipelineConfig};
use sigtree::rng::Rng;
use sigtree::signal::generate;
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(11);
    let sig = generate::smooth(4096, 256, 5, &mut rng); // ~1M cells
    let n = sig.len();
    println!("signal: {}x{} = {n} cells", sig.rows(), sig.cols());

    // Band-size ablation.
    let mut table = Table::new(&["band rows", "workers", "median", "cells/s", "blocks"]);
    for band in [64usize, 256, 1024] {
        let cfg = PipelineConfig::new(CoresetConfig::new(32, 0.25))
            .with_band_rows(band)
            .with_workers(1);
        let t = bench(0, 3, Duration::from_secs(10), || run(&sig, cfg));
        let (cs, _) = run(&sig, cfg);
        table.row(&[
            band.to_string(),
            "1".into(),
            fmt_duration(t.median),
            fmt_f(n as f64 / t.median.as_secs_f64()),
            cs.blocks.len().to_string(),
        ]);
    }
    // Worker-count ablation (single-core hardware: expect ~flat, shows
    // coordination overhead rather than speedup).
    for workers in [1usize, 2, 4] {
        let cfg = PipelineConfig::new(CoresetConfig::new(32, 0.25))
            .with_band_rows(256)
            .with_workers(workers);
        let t = bench(0, 3, Duration::from_secs(10), || run(&sig, cfg));
        let (cs, _) = run(&sig, cfg);
        table.row(&[
            "256".into(),
            workers.to_string(),
            fmt_duration(t.median),
            fmt_f(n as f64 / t.median.as_secs_f64()),
            cs.blocks.len().to_string(),
        ]);
    }
    table.print("pipeline throughput: band-size and worker ablations");

    // Batch (monolithic) baseline for reference.
    let t = bench(0, 3, Duration::from_secs(10), || {
        sigtree::coreset::SignalCoreset::construct(&sig, 32, 0.25)
    });
    println!(
        "\nbatch (no pipeline) baseline: {} ({:.2e} cells/s)",
        fmt_duration(t.median),
        n as f64 / t.median.as_secs_f64()
    );
}
