//! Regenerates **Figure 4** of the paper (all four panels) on the
//! UCI-substitute datasets (DESIGN.md §Substitutions, experiments E1–E4 +
//! the headline ×10 claim E11).
//!
//! * top panels   — test-set SSE vs compression size, coreset vs uniform
//!                  sample (tuning on the compression, forest trained with
//!                  the tuned k);
//! * bottom-left  — loss(+k/1e5) vs k curves: full data vs coreset sizes;
//! * bottom-right — total time (compression + tuning) vs compression size.
//!
//! Scale is controlled by SIGTREE_FIG4_SCALE (default 0.15 of the UCI
//! sizes to keep single-core CI runs in minutes; EXPERIMENTS.md records
//! both the default and a full-scale run).

use sigtree::benchkit::{fmt_duration, fmt_f, Table};
use sigtree::datasets;
use sigtree::experiments::tuning::{log_grid, tune_coreset, tune_full, tune_uniform};
use sigtree::experiments::Solver;
use sigtree::rng::Rng;

fn main() {
    let scale: f64 = std::env::var("SIGTREE_FIG4_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.15);
    let mut rng = Rng::new(2021);
    for (name, signal) in [
        ("air-quality-like", datasets::air_quality_like(scale, &mut rng)),
        ("gesture-phase-like", datasets::gesture_phase_like(scale, &mut rng)),
    ] {
        let (masked, held) = datasets::holdout_patches(&signal, 0.3, 5, &mut rng);
        println!(
            "\n=== Fig. 4 / {name}: {}x{}, train {}, held {} ===",
            signal.rows(),
            signal.cols(),
            masked.present(),
            held.len()
        );
        let grid = log_grid(8, 512, 6);

        // Top panels: accuracy vs compression size (ε sweep).
        let mut top = Table::new(&[
            "eps",
            "size",
            "size %",
            "coreset SSE",
            "uniform SSE",
            "full SSE",
        ]);
        let full = tune_full(&masked, &held, &grid, Solver::RandomForest, 1);
        let full_best = best_sse(&full.points, full.best_k());
        for eps in [0.5, 0.4, 0.3, 0.2] {
            let core = tune_coreset(&masked, &held, &grid, 500, eps, Solver::RandomForest, 1);
            let uni = tune_uniform(
                &masked,
                &held,
                &grid,
                core.compression_size,
                Solver::RandomForest,
                1,
            );
            top.row(&[
                format!("{eps}"),
                core.compression_size.to_string(),
                format!(
                    "{:.2}",
                    100.0 * core.compression_size as f64 / masked.present() as f64
                ),
                fmt_f(best_sse(&core.points, core.best_k())),
                fmt_f(best_sse(&uni.points, uni.best_k())),
                fmt_f(full_best),
            ]);
        }
        top.print(&format!("{name}: Fig 4 top (SSE vs compression size)"));

        // Bottom-left: the tuning curve ℓ + k/1e5 per k.
        let core_small = tune_coreset(&masked, &held, &grid, 500, 0.4, Solver::RandomForest, 2);
        let core_large = tune_coreset(&masked, &held, &grid, 500, 0.2, Solver::RandomForest, 2);
        let mut bl = Table::new(&["k", "full", "coreset(small)", "coreset(large)"]);
        for (i, &k) in grid.iter().enumerate() {
            let reg = k as f64 / 1e5;
            bl.row(&[
                k.to_string(),
                fmt_f(full.points[i].1 + reg),
                fmt_f(core_small.points[i].1 + reg),
                fmt_f(core_large.points[i].1 + reg),
            ]);
        }
        bl.print(&format!("{name}: Fig 4 bottom-left (loss + k/1e5 vs k)"));

        // Bottom-right: total tuning time vs compression size.
        let mut br = Table::new(&["scheme", "size", "total time", "speedup vs full"]);
        let base = full.total_time.as_secs_f64();
        br.row(&[
            "full".into(),
            full.compression_size.to_string(),
            fmt_duration(full.total_time),
            "x1.0".into(),
        ]);
        for (label, curve) in [("coreset ε=0.4", &core_small), ("coreset ε=0.2", &core_large)] {
            br.row(&[
                label.into(),
                curve.compression_size.to_string(),
                fmt_duration(curve.total_time),
                format!("x{:.1}", base / curve.total_time.as_secs_f64().max(1e-9)),
            ]);
        }
        br.print(&format!("{name}: Fig 4 bottom-right (tuning time)"));
    }
}

fn best_sse(points: &[(usize, f64)], best_k: usize) -> f64 {
    points
        .iter()
        .find(|(k, _)| *k == best_k)
        .map(|&(_, l)| l)
        .unwrap_or(f64::NAN)
}
