//! Forest/GBDT training benchmarks: train-on-coreset vs train-on-full —
//! the source of the paper's headline ×10 (E11, solver side).

use sigtree::benchkit::{bench, fmt_duration, fmt_f, Table};
use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::datasets;
use sigtree::rng::Rng;
use sigtree::tree::forest::{ForestParams, RandomForest};
use sigtree::tree::gbdt::{Gbdt, GbdtParams};
use sigtree::tree::{DecisionTree, Sample, TreeParams};
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(10);
    let sig = datasets::air_quality_like(0.25, &mut rng);
    let (masked, held) = datasets::holdout_patches(&sig, 0.3, 5, &mut rng);
    let full: Vec<Sample> = datasets::signal_to_samples(&masked);
    let cs = SignalCoreset::construct(&masked, 500, 0.3);
    let core: Vec<Sample> = cs.weighted_points().iter().map(Sample::from_point).collect();
    println!(
        "train set {} cells, coreset {} pts ({:.2}%)",
        full.len(),
        core.len(),
        100.0 * core.len() as f64 / full.len() as f64
    );

    let sse = |pred: &dyn Fn(&[f64]) -> f64| -> f64 {
        held.iter()
            .map(|&(r, c, y)| (pred(&[r as f64, c as f64]) - y).powi(2))
            .sum()
    };

    let mut table = Table::new(&["solver", "data", "train (median)", "test SSE", "speedup"]);
    // Single CART tree.
    let tp = TreeParams::default().with_max_leaves(64);
    let t_full = bench(0, 3, Duration::from_secs(20), || {
        DecisionTree::fit(&full, &tp, None)
    });
    let t_core = bench(0, 5, Duration::from_secs(10), || {
        DecisionTree::fit(&core, &tp, None)
    });
    let m_full = DecisionTree::fit(&full, &tp, None);
    let m_core = DecisionTree::fit(&core, &tp, None);
    let base = t_full.median.as_secs_f64();
    table.row(&[
        "CART".into(),
        "full".into(),
        fmt_duration(t_full.median),
        fmt_f(sse(&|x| m_full.predict(x))),
        "x1.0".into(),
    ]);
    table.row(&[
        "CART".into(),
        "coreset".into(),
        fmt_duration(t_core.median),
        fmt_f(sse(&|x| m_core.predict(x))),
        format!("x{:.1}", base / t_core.median.as_secs_f64()),
    ]);

    // Random forest (10 trees).
    let fp = ForestParams::default().with_trees(10).with_max_leaves(64);
    let t_full = bench(0, 3, Duration::from_secs(30), || {
        RandomForest::fit(&full, &fp, &mut Rng::new(1))
    });
    let t_core = bench(0, 5, Duration::from_secs(10), || {
        RandomForest::fit(&core, &fp, &mut Rng::new(1))
    });
    let f_full = RandomForest::fit(&full, &fp, &mut Rng::new(1));
    let f_core = RandomForest::fit(&core, &fp, &mut Rng::new(1));
    let base = t_full.median.as_secs_f64();
    table.row(&[
        "RandomForest".into(),
        "full".into(),
        fmt_duration(t_full.median),
        fmt_f(sse(&|x| f_full.predict(x))),
        "x1.0".into(),
    ]);
    table.row(&[
        "RandomForest".into(),
        "coreset".into(),
        fmt_duration(t_core.median),
        fmt_f(sse(&|x| f_core.predict(x))),
        format!("x{:.1}", base / t_core.median.as_secs_f64()),
    ]);

    // GBDT (LightGBM substitute).
    let gp = GbdtParams::default().with_stages(20).with_leaves(31);
    let t_full = bench(0, 3, Duration::from_secs(30), || {
        Gbdt::fit(&full, &gp, &mut Rng::new(2))
    });
    let t_core = bench(0, 5, Duration::from_secs(10), || {
        Gbdt::fit(&core, &gp, &mut Rng::new(2))
    });
    let g_full = Gbdt::fit(&full, &gp, &mut Rng::new(2));
    let g_core = Gbdt::fit(&core, &gp, &mut Rng::new(2));
    let base = t_full.median.as_secs_f64();
    table.row(&[
        "GBDT".into(),
        "full".into(),
        fmt_duration(t_full.median),
        fmt_f(sse(&|x| g_full.predict(x))),
        "x1.0".into(),
    ]);
    table.row(&[
        "GBDT".into(),
        "coreset".into(),
        fmt_duration(t_core.median),
        fmt_f(sse(&|x| g_core.predict(x))),
        format!("x{:.1}", base / t_core.median.as_secs_f64()),
    ]);
    table.print("E11: solver training, full vs coreset (air-quality-like, 25% scale)");
}
