//! Forest/GBDT tuning benchmark: tune-on-compression vs tune-on-full —
//! the paper's headline ×10 (E11, solver side), now driven by the
//! shared [`sigtree::experiments::x10`] harness so the CLI `x10`
//! subcommand, this bench, and the bench gate's `forest` pair all
//! measure the identical protocol.
//!
//! Emits `BENCH_forest.json` in the working directory (`rust/` under
//! `cargo bench`). `--quick` runs the CI-sized configuration; the
//! default is the experiment-sized sweep.

use sigtree::experiments::x10;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let config = if quick { x10::X10Config::quick() } else { x10::X10Config::full() };

    println!(
        "E11: tuning on compression vs full (air-quality-like, scale {}, grid {}{})",
        config.scale,
        config.grid,
        if quick { ", --quick" } else { "" }
    );
    let rows = x10::run(&config);
    print!("{}", x10::summary(&rows));

    let doc = x10::report_json(&config, &rows);
    let path = "BENCH_forest.json";
    match std::fs::write(path, doc.render() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
