//! Regenerates **Figures 5–7** (appendix) numerically: blobs / moons /
//! circles point datasets → rasterized signal → balanced partition →
//! weighted coreset → decision tree trained on the coreset vs. on the
//! full data (experiments E5–E7). The paper reports these as images; we
//! report the quantities the captions call out: partition set count,
//! coreset percentage, and the agreement between tree-on-coreset and
//! tree-on-full.

use sigtree::benchkit::{fmt_f, Table};
use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::datasets::{self, Point2};
use sigtree::rng::Rng;
use sigtree::signal::PrefixStats;
use sigtree::tree::{DecisionTree, Sample, TreeParams};

fn main() {
    let scale: f64 = std::env::var("SIGTREE_FIG567_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let mut rng = Rng::new(5);
    let sets: Vec<(&str, Vec<Point2>, f64)> = vec![
        ("fig5_blobs", datasets::blobs(scale, &mut rng), 0.06),
        ("fig6_moons", datasets::moons(scale, 0.08, &mut rng), 0.08),
        ("fig7_circles", datasets::circles(scale, 0.08, &mut rng), 0.14),
    ];
    let mut table = Table::new(&[
        "figure",
        "points",
        "grid",
        "partition sets",
        "coreset %",
        "paper %",
        "tree SSE (full)",
        "tree SSE (coreset)",
    ]);
    for (name, points, paper_pct) in sets {
        let grid = 128usize;
        let sig = datasets::rasterize(&points, grid, grid);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 2000.min(sig.present() / 8).max(8), 0.2);
        let full_samples = datasets::signal_to_samples(&sig);
        let cs_samples: Vec<Sample> = cs
            .weighted_points()
            .iter()
            .map(Sample::from_point)
            .collect();
        let params = TreeParams::default().with_max_leaves(64);
        let t_full = DecisionTree::fit(&full_samples, &params, None);
        let t_core = DecisionTree::fit(&cs_samples, &params, None);
        // Both trees evaluated on the full rasterized data (the caption's
        // "resembles the tree trained on the full data").
        let sse_full = t_full.sse(&full_samples);
        let sse_core = t_core.sse(&full_samples);
        table.row(&[
            name.into(),
            points.len().to_string(),
            format!("{grid}x{grid}"),
            cs.blocks.len().to_string(),
            format!("{:.1}", 100.0 * cs.stored_points() as f64 / sig.present() as f64),
            format!("{:.0}", 100.0 * paper_pct),
            fmt_f(sse_full),
            fmt_f(sse_core),
        ]);
        let _ = stats;
    }
    table.print("Figs 5-7: partition size, coreset %, tree-on-coreset vs tree-on-full");
    println!(
        "\nshape check: coreset %% should be in the same regime as the paper's\n\
         6/8/14%% captions, and tree-on-coreset SSE within ~1.5x of tree-on-full."
    );
}
