//! PJRT runtime benchmarks: artifact execution throughput vs native Rust
//! for the same statistics (the L1/L2 perf pass measurements recorded in
//! EXPERIMENTS.md §Perf). Skips cleanly when artifacts are absent.

use sigtree::benchkit::{bench, fmt_duration, fmt_f, Table};
use sigtree::rng::Rng;
use sigtree::runtime::{artifacts_available, pad_integral, Runtime, RECT_BATCH, TILE};
use sigtree::signal::{PrefixStats, Rect, Signal};
use std::time::Duration;

fn main() {
    if !artifacts_available() {
        println!("bench_runtime: artifacts not built (run `make artifacts`) — skipping");
        return;
    }
    let rt = Runtime::load_default().expect("runtime load");
    println!("platform: {}, artifacts: {:?}", rt.platform(), rt.artifact_names());

    let mut rng = Rng::new(12);
    let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
    let sig = Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);

    let mut table = Table::new(&["op", "impl", "median", "throughput"]);

    // prefix2d: PJRT vs native.
    let t_pjrt = bench(1, 8, Duration::from_secs(4), || rt.prefix2d(&tile).unwrap());
    let t_native = bench(1, 8, Duration::from_secs(4), || PrefixStats::new(&sig));
    let cells = (TILE * TILE) as f64;
    table.row(&[
        "prefix2d (integral images)".into(),
        "PJRT f32".into(),
        fmt_duration(t_pjrt.median),
        format!("{} cells/s", fmt_f(cells / t_pjrt.median.as_secs_f64())),
    ]);
    table.row(&[
        "prefix2d (integral images)".into(),
        "native f64".into(),
        fmt_duration(t_native.median),
        format!("{} cells/s", fmt_f(cells / t_native.median.as_secs_f64())),
    ]);

    // block_sse: PJRT batched vs native loop.
    let (ii_y, ii_y2) = rt.prefix2d(&tile).unwrap();
    let p_y = pad_integral(&ii_y);
    let p_y2 = pad_integral(&ii_y2);
    let rects: Vec<[i32; 4]> = (0..RECT_BATCH)
        .map(|_| {
            let r0 = rng.usize(TILE);
            let r1 = rng.range(r0, TILE);
            let c0 = rng.usize(TILE);
            let c1 = rng.range(c0, TILE);
            [r0 as i32, r1 as i32, c0 as i32, c1 as i32]
        })
        .collect();
    let native_rects: Vec<Rect> = rects
        .iter()
        .map(|r| Rect::new(r[0] as usize, r[1] as usize, r[2] as usize, r[3] as usize))
        .collect();
    let stats = PrefixStats::new(&sig);
    let t_pjrt = bench(1, 8, Duration::from_secs(4), || {
        rt.block_sse(&p_y, &p_y2, &rects).unwrap()
    });
    let t_native = bench(1, 8, Duration::from_secs(4), || {
        native_rects.iter().map(|r| stats.opt1(r)).sum::<f64>()
    });
    table.row(&[
        format!("block_sse ({RECT_BATCH} rects)"),
        "PJRT f32".into(),
        fmt_duration(t_pjrt.median),
        format!("{} rects/s", fmt_f(RECT_BATCH as f64 / t_pjrt.median.as_secs_f64())),
    ]);
    table.row(&[
        format!("block_sse ({RECT_BATCH} rects)"),
        "native f64".into(),
        fmt_duration(t_native.median),
        format!("{} rects/s", fmt_f(RECT_BATCH as f64 / t_native.median.as_secs_f64())),
    ]);

    // seg_loss.
    let rendered: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
    let t_pjrt = bench(1, 8, Duration::from_secs(4), || {
        rt.seg_loss(&tile, &rendered).unwrap()
    });
    table.row(&[
        "seg_loss (SSE of tile)".into(),
        "PJRT f32".into(),
        fmt_duration(t_pjrt.median),
        format!("{} cells/s", fmt_f(cells / t_pjrt.median.as_secs_f64())),
    ]);

    table.print("PJRT artifact execution vs native (TILE=256)");
    println!(
        "\nnote: PJRT CPU runs the interpret-lowered Pallas kernels; real-TPU\n\
         projections are derived from VMEM/bytes-moved analysis in DESIGN.md §Perf,\n\
         not from these CPU timings."
    );
}
