//! Kernel-backend benchmarks: artifact-contract execution throughput per
//! backend vs the native f64 statistics for the same quantities (the
//! L1/L2 perf pass measurements recorded in EXPERIMENTS.md §Perf), plus
//! the machine-readable `BENCH_runtime.json` evidence trail consumed by
//! `scripts/bench_gate.sh` — the perf regression gate.
//!
//! Always benches the pure-Rust `NativeBackend` and the cache-blocked
//! `BlockedBackend`; with `--features pjrt` and the artifacts built, the
//! PJRT backend is benched side by side. `--quick` shrinks budgets,
//! thread sweeps, and the big prefix build for CI smoke runs (rows are
//! keyed by their op string, so a quick row never gates against a
//! full-run baseline row of a different size).

use sigtree::benchkit::{bench, fmt_duration, fmt_f, Table};
use sigtree::coreset::{CoresetConfig, SignalCoreset};
use sigtree::engine::{Engine, EngineConfig};
use sigtree::json::Json;
use sigtree::rng::Rng;
use sigtree::runtime::{
    pad_integral, BlockedBackend, KernelBackend, NativeBackend, RECT_BATCH, TILE,
};
use sigtree::segmentation::{random_segmentation, KSegmentation};
use sigtree::signal::{generate, PrefixStats, Rect, Signal};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Counting wrapper around the system allocator, so the zero-copy build
/// path's allocation profile is a first-class bench output: before the
/// SignalView/shared-stats refactor every shard paid an O(area) crop
/// plus three O(area) integral images; now shards are `(&PrefixStats,
/// Rect)` windows and per-shard allocations are small and flat.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

fn alloc_snapshot() -> (u64, u64) {
    (
        ALLOC_CALLS.load(Ordering::Relaxed),
        ALLOC_BYTES.load(Ordering::Relaxed),
    )
}

#[cfg(feature = "pjrt")]
fn pjrt_backend() -> Option<Box<dyn KernelBackend>> {
    if !sigtree::runtime::artifacts_available() {
        println!("bench_runtime: PJRT artifacts not built (run `make artifacts`) — native only");
        return None;
    }
    match sigtree::runtime::pjrt::Runtime::load_default() {
        Ok(rt) => Some(Box::new(rt)),
        Err(e) => {
            println!("bench_runtime: pjrt backend unavailable ({e}) — native only");
            None
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend() -> Option<Box<dyn KernelBackend>> {
    None
}

fn main() {
    // `--quick` (CI smoke): 3 timed iters per row, 1 s budgets, a 1024²
    // big-build instead of 4096², and a reduced thread sweep.
    let quick = std::env::args().any(|a| a == "--quick");
    let budget = |secs: u64| Duration::from_secs(if quick { 1 } else { secs });
    let qiters = |full: usize| if quick { 3 } else { full };
    let thread_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };

    let mut backends: Vec<Box<dyn KernelBackend>> =
        vec![Box::new(NativeBackend::new()), Box::new(BlockedBackend::new())];
    if let Some(rt) = pjrt_backend() {
        backends.push(rt);
    }
    let names: Vec<String> = backends.iter().map(|b| b.name()).collect();
    println!("backends: {names:?}");

    let mut rng = Rng::new(12);
    let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
    let sig = Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);
    let cells = (TILE * TILE) as f64;

    let rects: Vec<[i32; 4]> = (0..RECT_BATCH)
        .map(|_| {
            let r0 = rng.usize(TILE);
            let r1 = rng.range(r0, TILE);
            let c0 = rng.usize(TILE);
            let c1 = rng.range(c0, TILE);
            [r0 as i32, r1 as i32, c0 as i32, c1 as i32]
        })
        .collect();
    let native_rects: Vec<Rect> = rects
        .iter()
        .map(|r| Rect::new(r[0] as usize, r[1] as usize, r[2] as usize, r[3] as usize))
        .collect();
    let rendered: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();

    let mut table = Table::new(&["op", "impl", "median", "p90", "throughput"]);
    // (op, impl, median_s) triples feeding the kernels / blocked_speedup
    // arrays in BENCH_runtime.json.
    let mut kernel_meds: Vec<(String, String, f64)> = Vec::new();

    // f64 reference rows (PrefixStats — the exact oracle the kernels
    // approximate).
    let t_ref = bench(1, qiters(8), budget(4), || PrefixStats::new(&sig));
    table.row(&[
        "prefix2d (integral images)".into(),
        "f64 PrefixStats".into(),
        fmt_duration(t_ref.median),
        fmt_duration(t_ref.p90),
        format!("{} cells/s", fmt_f(cells / t_ref.median.as_secs_f64())),
    ]);
    kernel_meds.push(("prefix2d".into(), "f64-stats".into(), t_ref.median.as_secs_f64()));
    let stats = PrefixStats::new(&sig);
    let t_ref = bench(1, qiters(8), budget(4), || {
        native_rects.iter().map(|r| stats.opt1(r)).sum::<f64>()
    });
    table.row(&[
        format!("block_sse ({RECT_BATCH} rects)"),
        "f64 PrefixStats".into(),
        fmt_duration(t_ref.median),
        fmt_duration(t_ref.p90),
        format!("{} rects/s", fmt_f(RECT_BATCH as f64 / t_ref.median.as_secs_f64())),
    ]);
    kernel_meds.push(("block_sse".into(), "f64-stats".into(), t_ref.median.as_secs_f64()));

    // Per-backend kernel rows.
    for backend in &backends {
        let name = backend.name();
        let t = bench(1, qiters(8), budget(4), || backend.prefix2d(&tile).unwrap());
        table.row(&[
            "prefix2d (integral images)".into(),
            name.clone(),
            fmt_duration(t.median),
            fmt_duration(t.p90),
            format!("{} cells/s", fmt_f(cells / t.median.as_secs_f64())),
        ]);
        kernel_meds.push(("prefix2d".into(), name.clone(), t.median.as_secs_f64()));

        let (ii_y, ii_y2) = backend.prefix2d(&tile).unwrap();
        let p_y = pad_integral(&ii_y);
        let p_y2 = pad_integral(&ii_y2);
        let t = bench(1, qiters(8), budget(4), || {
            backend.block_sse(&p_y, &p_y2, &rects).unwrap()
        });
        table.row(&[
            format!("block_sse ({RECT_BATCH} rects)"),
            name.clone(),
            fmt_duration(t.median),
            fmt_duration(t.p90),
            format!("{} rects/s", fmt_f(RECT_BATCH as f64 / t.median.as_secs_f64())),
        ]);
        kernel_meds.push(("block_sse".into(), name.clone(), t.median.as_secs_f64()));

        let t = bench(1, qiters(8), budget(4), || {
            backend.seg_loss(&tile, &rendered).unwrap()
        });
        table.row(&[
            "seg_loss (SSE of tile)".into(),
            name.clone(),
            fmt_duration(t.median),
            fmt_duration(t.p90),
            format!("{} cells/s", fmt_f(cells / t.median.as_secs_f64())),
        ]);
        kernel_meds.push(("seg_loss".into(), name, t.median.as_secs_f64()));
    }

    table.print("kernel backends vs f64 reference (TILE=256)");

    // Blocked-vs-native speedup rows (the headline tentpole measurement;
    // both backends were asserted bit-identical / pinned-tolerance by the
    // differential suites, so these compare identical outputs).
    let med_of = |op: &str, imp: &str| {
        kernel_meds.iter().find(|(o, i, _)| o == op && i == imp).map(|&(_, _, m)| m)
    };
    let mut speedup_rows: Vec<Json> = Vec::new();
    for op in ["prefix2d", "block_sse", "seg_loss"] {
        if let (Some(n), Some(b)) = (med_of(op, "native"), med_of(op, "blocked")) {
            println!("blocked speedup vs native [{op}]: x{:.2}", n / b.max(1e-12));
            speedup_rows.push(Json::obj(vec![
                ("op", Json::str(op)),
                ("native_median_s", Json::num(n)),
                ("blocked_median_s", Json::num(b)),
                ("speedup_vs_native", Json::num(n / b.max(1e-12))),
            ]));
        }
    }
    let kernel_rows: Vec<Json> = kernel_meds
        .iter()
        .map(|(op, imp, med)| {
            Json::obj(vec![
                ("op", Json::str(op.as_str())),
                ("impl", Json::str(imp.as_str())),
                ("median_s", Json::num(*med)),
            ])
        })
        .collect();

    // ---- big prefix build: scalar vs cache-blocked fill ------------------
    // The tentpole row: one full three-image PrefixStats build on a large
    // signal, scalar band fill (`new_par`) vs cache-blocked two-pass fill
    // (`new_blocked`, default block). Bit-identity is asserted before
    // timing, so the speedup compares *identical* outputs.
    let mut pb_rows: Vec<Json> = Vec::new();
    {
        let big = if quick { 1024 } else { 4096 };
        let mut rng_big = Rng::new(33);
        let sig_big = generate::smooth(big, big, 5, &mut rng_big);
        let whole = sig_big.bounds();
        assert_eq!(
            PrefixStats::new_par(&sig_big, 1).moments(&whole),
            PrefixStats::new_blocked(&sig_big, 1, 0).moments(&whole),
            "blocked fill must be bit-identical to the scalar fill"
        );
        let pb_threads: &[usize] = if quick { &[1] } else { &[1, 4] };
        let mut pb_table = Table::new(&["op", "impl", "threads", "median", "p90", "speedup"]);
        for &t in pb_threads {
            let t_scalar =
                bench(1, qiters(4), budget(8), || PrefixStats::new_par(&sig_big, t));
            let t_blocked =
                bench(1, qiters(4), budget(8), || PrefixStats::new_blocked(&sig_big, t, 0));
            let (ss, bs) = (t_scalar.median.as_secs_f64(), t_blocked.median.as_secs_f64());
            for (imp, tm, speed) in
                [("scalar", t_scalar, 1.0), ("blocked", t_blocked, ss / bs.max(1e-12))]
            {
                pb_table.row(&[
                    format!("prefix_build ({big}x{big})"),
                    imp.into(),
                    format!("{t}"),
                    fmt_duration(tm.median),
                    fmt_duration(tm.p90),
                    format!("x{speed:.2}"),
                ]);
                pb_rows.push(Json::obj(vec![
                    ("op", Json::str(format!("prefix_build ({big}x{big})"))),
                    ("impl", Json::str(imp)),
                    ("threads", Json::int(t)),
                    ("median_s", Json::num(tm.median.as_secs_f64())),
                    ("p90_s", Json::num(tm.p90.as_secs_f64())),
                    ("speedup_vs_scalar", Json::num(speed)),
                ]));
            }
        }
        pb_table.print("full prefix-statistics build: scalar vs blocked fill");
    }

    // ---- sigtree::par thread scaling ------------------------------------
    // The acceptance case: 512×512 smooth signal, k=64, ε=0.2 — parallel
    // sharded coreset construction, parallel prefix statistics, and the
    // batch fitting-loss API at 1/2/4/8 worker threads.
    let mut rng = Rng::new(21);
    let sig512 = generate::smooth(512, 512, 4, &mut rng);
    let config = CoresetConfig::new(64, 0.2);
    let stats512 = PrefixStats::new(&sig512);
    let queries: Vec<KSegmentation> = (0..64)
        .map(|_| {
            let mut s = random_segmentation(sig512.bounds(), 64, &mut rng);
            s.refit_values(&stats512);
            s
        })
        .collect();
    let cs512 = SignalCoreset::construct_sharded(&sig512, config, 0);

    let ops = [
        "build_par (512x512 smooth, k=64)",
        "PrefixStats::new_par (512x512)",
        "fitting_loss_batch (64 queries, k=64)",
    ];
    let mut par_table = Table::new(&["op", "threads", "median", "speedup vs 1T"]);
    let mut bases = [0.0f64; 3];
    // Machine-readable rows for BENCH_runtime.json (same writer as the
    // audit's evidence trail), so the repo's perf trajectory is diffable
    // run over run instead of living only in stdout tables.
    let mut scaling_rows: Vec<Json> = Vec::new();
    for &t in thread_counts {
        let medians = [
            bench(1, qiters(4), budget(6), || {
                SignalCoreset::construct_sharded(&sig512, config, t)
            })
            .median,
            bench(1, qiters(6), budget(2), || PrefixStats::new_par(&sig512, t)).median,
            bench(1, qiters(6), budget(2), || {
                cs512.fitting_loss_batch(&queries, t)
            })
            .median,
        ];
        for i in 0..ops.len() {
            let med = medians[i].as_secs_f64();
            if t == 1 {
                bases[i] = med;
            }
            par_table.row(&[
                ops[i].into(),
                format!("{t}"),
                fmt_duration(medians[i]),
                format!("x{:.2}", bases[i] / med.max(1e-12)),
            ]);
            scaling_rows.push(Json::obj(vec![
                ("op", Json::str(ops[i])),
                ("threads", Json::int(t)),
                ("median_s", Json::num(med)),
                ("speedup_vs_1t", Json::num(bases[i] / med.max(1e-12))),
            ]));
        }
    }
    par_table.print("sigtree::par thread scaling (512x512 acceptance case)");
    println!(
        "\nnote: speedups are vs the 1-thread run of the same op on this machine\n\
         ({} cores available); shard plans are thread-independent, so every row\n\
         computes the bit-identical result.",
        sigtree::par::available_threads()
    );

    // ---- engine reuse vs per-call spinup ---------------------------------
    // The serving scenario: 100 repeated fitting-loss batches. One
    // long-lived Engine keeps its workers parked between batches; the
    // legacy path spawns (and joins) scoped threads on every call. Same
    // results bit-for-bit — this row measures pure dispatch overhead.
    const REUSE_BATCHES: usize = 100;
    let reuse_threads = 4usize;
    let engine = Engine::new(EngineConfig::new(64, 0.2).with_threads(reuse_threads))
        .expect("valid engine config");
    assert_eq!(
        engine.fitting_loss(&cs512, &queries),
        cs512.fitting_loss_batch(&queries, reuse_threads),
        "engine pool and spawn-per-call must agree exactly"
    );
    let mut reuse_table = Table::new(&["op", "mode", "median", "batches/s"]);
    let mut reuse_rows: Vec<Json> = Vec::new();
    let engine_timing = bench(1, qiters(4), budget(6), || {
        for _ in 0..REUSE_BATCHES {
            engine.fitting_loss(&cs512, &queries);
        }
    });
    let spawn_timing = bench(1, qiters(4), budget(6), || {
        for _ in 0..REUSE_BATCHES {
            cs512.fitting_loss_batch(&queries, reuse_threads);
        }
    });
    for (mode, t) in [("engine-pool", engine_timing), ("spawn-per-call", spawn_timing)] {
        let med = t.median.as_secs_f64();
        reuse_table.row(&[
            format!("fitting_loss x{REUSE_BATCHES} (64 queries, k=64)"),
            mode.into(),
            fmt_duration(t.median),
            fmt_f(REUSE_BATCHES as f64 / med.max(1e-12)),
        ]);
        reuse_rows.push(Json::obj(vec![
            ("op", Json::str(format!("fitting_loss x{REUSE_BATCHES}"))),
            ("mode", Json::str(mode)),
            ("threads", Json::int(reuse_threads)),
            ("batches", Json::int(REUSE_BATCHES)),
            ("median_s", Json::num(med)),
            ("batches_per_s", Json::num(REUSE_BATCHES as f64 / med.max(1e-12))),
        ]));
    }
    reuse_table.print("engine reuse: one WorkerPool across batches vs scoped threads per call");

    // ---- zero-copy allocation profile -----------------------------------
    // One uninstrumented run per thread count (outside `bench` so warmup
    // repetitions don't inflate the counters). The one-time shared
    // PrefixStats (3 integral arrays, ~6 MiB at 512²) is measured
    // separately and subtracted, so the per-shard columns show only the
    // shard-attributable allocations — which stay small and flat in the
    // shard area now that shards are `(&PrefixStats, Rect)` windows
    // instead of O(area) crops + per-shard integral rebuilds.
    let shards = (512 / 64) as f64;
    let mut alloc_table = Table::new(&[
        "op",
        "threads",
        "allocs total",
        "stats allocs",
        "allocs/shard",
        "KiB/shard",
    ]);
    let mut alloc_rows: Vec<Json> = Vec::new();
    for &t in thread_counts {
        let (c0, b0) = alloc_snapshot();
        let stats_probe = PrefixStats::new_par(&sig512, t);
        let (c1, b1) = alloc_snapshot();
        drop(stats_probe);
        let cs = SignalCoreset::construct_sharded(&sig512, config, t);
        let (c2, b2) = alloc_snapshot();
        let stats_allocs = (c1 - c0) as f64;
        let stats_bytes = (b1 - b0) as f64;
        let shard_allocs = ((c2 - c1) as f64 - stats_allocs).max(0.0);
        let shard_kib = ((b2 - b1) as f64 - stats_bytes).max(0.0) / 1024.0;
        alloc_table.row(&[
            format!("build_par (512x512, {} blocks)", cs.blocks.len()),
            format!("{t}"),
            fmt_f((c2 - c1) as f64),
            fmt_f(stats_allocs),
            fmt_f(shard_allocs / shards),
            fmt_f(shard_kib / shards),
        ]);
        alloc_rows.push(Json::obj(vec![
            ("threads", Json::int(t)),
            ("blocks", Json::int(cs.blocks.len())),
            ("allocs_total", Json::num((c2 - c1) as f64)),
            ("stats_allocs", Json::num(stats_allocs)),
            ("allocs_per_shard", Json::num(shard_allocs / shards)),
            ("kib_per_shard", Json::num(shard_kib / shards)),
        ]));
    }
    alloc_table.print(
        "allocation counts on the build path (8 shards; shared-stats cost subtracted)",
    );

    // prefix2d scratch reuse: the `prefix2d` entry point must allocate
    // two fresh TILE² images per call; the `prefix2d_into` entry point
    // reuses caller buffers, so repeated calls allocate only on the
    // first (buffer growth) — the hoisted-allocation win, counted.
    let native = NativeBackend::new();
    let (c0, _) = alloc_snapshot();
    for _ in 0..8 {
        std::hint::black_box(native.prefix2d(&tile).unwrap());
    }
    let (c1, _) = alloc_snapshot();
    let (mut scratch_y, mut scratch_y2) = (Vec::new(), Vec::new());
    for _ in 0..8 {
        native.prefix2d_into(&tile, &mut scratch_y, &mut scratch_y2).unwrap();
        std::hint::black_box((&scratch_y, &scratch_y2));
    }
    let (c2, _) = alloc_snapshot();
    println!(
        "\nprefix2d allocation profile (8 calls, one {TILE}x{TILE} tile):\n  \
         fresh `prefix2d`:       {} allocs\n  \
         `prefix2d_into` reuse:  {} allocs (scratch buffers reused across calls)",
        c1 - c0,
        c2 - c1
    );
    alloc_rows.push(Json::obj(vec![
        ("op", Json::str("prefix2d x8 fresh")),
        ("allocs_total", Json::num((c1 - c0) as f64)),
    ]));
    alloc_rows.push(Json::obj(vec![
        ("op", Json::str("prefix2d_into x8 scratch-reuse")),
        ("allocs_total", Json::num((c2 - c1) as f64)),
    ]));

    // ---- incremental update vs full rebuild ------------------------------
    // The merge-tree payoff: one 64×64-tile edit on the 512×512
    // acceptance case through a long-lived EditSession (dirty leaf
    // rebuilt + O(log S) ancestor re-merge + stats refresh) vs a full
    // from-scratch sharded build of the same signal.
    let full_timing = bench(1, qiters(4), budget(6), || {
        SignalCoreset::construct_sharded(&sig512, config, reuse_threads)
    });
    let mut session = engine.edit_session(sig512.clone());
    let tile = Rect::new(192, 255, 192, 255); // one shard-interior 64×64 tile
    let update_timing = bench(1, qiters(8), budget(6), || {
        session.edit(tile, |_, _, v| v + 1e-3);
        session.coreset()
    });
    let (full_s, upd_s) = (full_timing.median.as_secs_f64(), update_timing.median.as_secs_f64());
    let mut inc_table = Table::new(&["op", "median", "speedup vs full"]);
    inc_table.row(&[
        "full rebuild (512x512, k=64)".into(),
        fmt_duration(full_timing.median),
        "x1.00".into(),
    ]);
    inc_table.row(&[
        "incremental_update (64x64 tile)".into(),
        fmt_duration(update_timing.median),
        format!("x{:.2}", full_s / upd_s.max(1e-12)),
    ]);
    inc_table.print("incremental update vs full rebuild (EditSession, 4 threads)");
    let inc_rows = vec![
        Json::obj(vec![
            ("op", Json::str("full_rebuild")),
            ("threads", Json::int(reuse_threads)),
            ("median_s", Json::num(full_s)),
            ("speedup_vs_full", Json::num(1.0)),
        ]),
        Json::obj(vec![
            ("op", Json::str("incremental_update")),
            ("tile_rows", Json::int(64)),
            ("tile_cols", Json::int(64)),
            ("threads", Json::int(reuse_threads)),
            ("median_s", Json::num(upd_s)),
            ("speedup_vs_full", Json::num(full_s / upd_s.max(1e-12))),
        ]),
    ];

    // ---- machine-readable evidence trail ---------------------------------
    let doc = Json::obj(vec![
        ("bench", Json::str("bench_runtime")),
        // "measured" (vs the committed bootstrap placeholder) tells
        // scripts/bench_gate.sh these medians are real timings it may
        // hard-gate against; "quick" flags reduced CI-smoke budgets.
        ("provenance", Json::str("measured")),
        ("quick", Json::Bool(quick)),
        (
            "acceptance_case",
            Json::obj(vec![
                ("rows", Json::int(512)),
                ("cols", Json::int(512)),
                ("k", Json::int(64)),
                ("eps", Json::num(0.2)),
            ]),
        ),
        (
            "available_threads",
            Json::int(sigtree::par::available_threads()),
        ),
        (
            "backends",
            Json::Arr(names.iter().map(|n| Json::str(n.as_str())).collect()),
        ),
        ("kernels", Json::Arr(kernel_rows)),
        ("blocked_speedup", Json::Arr(speedup_rows)),
        ("prefix_build", Json::Arr(pb_rows)),
        ("thread_scaling", Json::Arr(scaling_rows)),
        ("engine_reuse", Json::Arr(reuse_rows)),
        ("alloc_profile", Json::Arr(alloc_rows)),
        ("incremental_update", Json::Arr(inc_rows)),
    ]);
    match std::fs::write("BENCH_runtime.json", doc.render()) {
        Ok(()) => println!("\nwrote BENCH_runtime.json"),
        Err(e) => println!("\ncould not write BENCH_runtime.json: {e}"),
    }

    if names.iter().any(|n| n.starts_with("pjrt")) {
        println!(
            "\nnote: PJRT CPU runs the interpret-lowered Pallas kernels; real-TPU\n\
             projections are derived from VMEM/bytes-moved analysis in DESIGN.md §Perf,\n\
             not from these CPU timings."
        );
    }
}
