//! Loopback load generator for the `sigtree serve` daemon: per-request
//! p50/p99 latency and request throughput of `/fitting_loss` under
//! concurrent keep-alive clients, batched (collector window open) vs
//! unbatched (window 0), plus coreset-cache build-miss vs hit latency.
//! Emits the machine-readable `BENCH_serve.json` evidence trail consumed
//! by `scripts/bench_gate.sh` alongside `BENCH_runtime.json`.
//!
//! The server runs in-process on an ephemeral loopback port; clients are
//! plain threads writing hand-framed HTTP/1.1 (the same framing the
//! daemon speaks — `sigtree::serve::http`). `--quick` shrinks client
//! counts and request budgets for CI smoke runs; rows are keyed by
//! (endpoint, mode, clients, queries_per_request), so a quick row never
//! gates against a full-run row of a different shape.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use sigtree::benchkit::{fmt_f, Table};
use sigtree::engine::{Engine, EngineConfig};
use sigtree::json::Json;
use sigtree::serve::{http, ServeConfig, Server};
use sigtree::signal::Signal;

fn engine_config() -> EngineConfig {
    EngineConfig::new(8, 0.3).with_threads(4)
}

fn bench_signal(salt: f64) -> Signal {
    Signal::from_fn(128, 96, |r, c| ((5 * r + 3 * c) % 17) as f64 * 0.21 + salt)
}

fn signal_json(signal: &Signal) -> Json {
    let mut values = Vec::with_capacity(signal.len());
    for r in 0..signal.rows() {
        for c in 0..signal.cols() {
            values.push(Json::num(signal.get(r, c)));
        }
    }
    Json::obj(vec![
        ("rows", Json::int(signal.rows())),
        ("cols", Json::int(signal.cols())),
        ("values", Json::Arr(values)),
    ])
}

/// `queries` horizontal-stripe segmentations over the bench signal.
fn queries_json(rows: usize, cols: usize, queries: usize) -> Json {
    let mut out = Vec::new();
    for q in 0..queries {
        let pieces = 2 + q % 4;
        let step = rows / pieces;
        let mut arr = Vec::new();
        for i in 0..pieces {
            let r0 = i * step;
            let r1 = if i + 1 == pieces { rows - 1 } else { (i + 1) * step - 1 };
            arr.push(Json::obj(vec![
                ("r0", Json::int(r0)),
                ("r1", Json::int(r1)),
                ("c0", Json::int(0)),
                ("c1", Json::int(cols - 1)),
                ("value", Json::num(q as f64 * 0.13 + i as f64 / 7.0)),
            ]));
        }
        out.push(Json::obj(vec![("pieces", Json::Arr(arr))]));
    }
    Json::Arr(out)
}

fn start_server(batch_window_ms: u64) -> (SocketAddr, thread::JoinHandle<()>) {
    let engine = Engine::new(engine_config()).expect("engine");
    let cfg = ServeConfig { threads: 8, batch_window_ms, ..ServeConfig::default() };
    let server = Server::bind(engine, cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    let handle = thread::spawn(move || server.run().expect("serve run"));
    (addr, handle)
}

fn post(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, path: &str, body: &str) -> u16 {
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("send");
    stream.flush().expect("flush");
    let (status, resp) = http::read_response(reader).expect("response");
    assert_eq!(status, 200, "{path}: {resp}");
    status
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    let reader = BufReader::new(stream.try_clone().expect("clone"));
    (stream, reader)
}

fn shutdown(addr: SocketAddr, handle: thread::JoinHandle<()>) {
    let (mut stream, mut reader) = connect(addr);
    post(&mut stream, &mut reader, "/shutdown", "");
    drop((stream, reader));
    handle.join().expect("server thread");
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Drive `clients` concurrent keep-alive connections, each issuing
/// `requests` `/fitting_loss` POSTs; return (sorted latencies, wall
/// seconds).
fn run_load(
    addr: SocketAddr,
    clients: usize,
    requests: usize,
    body: Arc<String>,
) -> (Vec<f64>, f64) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..clients {
        let body = Arc::clone(&body);
        handles.push(thread::spawn(move || {
            let (mut stream, mut reader) = connect(addr);
            let mut lat = Vec::with_capacity(requests);
            for _ in 0..requests {
                let t = Instant::now();
                post(&mut stream, &mut reader, "/fitting_loss", &body);
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut all = Vec::new();
    for handle in handles {
        all.extend(handle.join().expect("client"));
    }
    let wall = t0.elapsed().as_secs_f64();
    all.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    (all, wall)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 8] };
    let requests_per_client = if quick { 25 } else { 100 };
    const QUERIES_PER_REQUEST: usize = 8;

    let signal = bench_signal(0.0);
    let sig_json = signal_json(&signal);
    let warm_body = Json::obj(vec![("signal", sig_json.clone())]).render();
    let fit_body = Arc::new(
        Json::obj(vec![
            ("signal", sig_json),
            ("queries", queries_json(signal.rows(), signal.cols(), QUERIES_PER_REQUEST)),
        ])
        .render(),
    );

    // ---- /fitting_loss latency & throughput: batched vs unbatched -------
    let mut table = Table::new(&["mode", "clients", "p50", "p99", "req/s"]);
    let mut fit_rows: Vec<Json> = Vec::new();
    for (mode, window_ms) in [("batched", 2u64), ("unbatched", 0u64)] {
        for &clients in client_counts {
            let (addr, handle) = start_server(window_ms);
            // Warm the coreset cache so rows measure query serving, not
            // the one-time build.
            let (mut stream, mut reader) = connect(addr);
            post(&mut stream, &mut reader, "/coreset", &warm_body);
            drop((stream, reader));

            let (lat, wall) = run_load(addr, clients, requests_per_client, Arc::clone(&fit_body));
            shutdown(addr, handle);

            let total = clients * requests_per_client;
            let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
            let rps = total as f64 / wall.max(1e-12);
            table.row(&[
                mode.into(),
                format!("{clients}"),
                format!("{:.3} ms", p50 * 1e3),
                format!("{:.3} ms", p99 * 1e3),
                fmt_f(rps),
            ]);
            fit_rows.push(Json::obj(vec![
                ("endpoint", Json::str("fitting_loss")),
                ("mode", Json::str(mode)),
                ("clients", Json::int(clients)),
                ("queries_per_request", Json::int(QUERIES_PER_REQUEST)),
                ("requests", Json::int(total)),
                ("median_s", Json::num(p50)),
                ("p99_s", Json::num(p99)),
                ("rps", Json::num(rps)),
            ]));
        }
    }
    table.print(&format!(
        "serve /fitting_loss ({QUERIES_PER_REQUEST} queries/request, keep-alive, {requests_per_client} req/client)"
    ));

    // ---- coreset cache: build-miss vs hit --------------------------------
    // Distinct salts → distinct content digests → every build is a real
    // miss; repeating one signal measures the rebuild-free hit path.
    let miss_samples = if quick { 3 } else { 5 };
    let hit_samples = if quick { 20 } else { 100 };
    let (addr, handle) = start_server(0);
    let (mut stream, mut reader) = connect(addr);
    let mut miss_lat = Vec::new();
    for i in 0..miss_samples {
        let body = Json::obj(vec![("signal", signal_json(&bench_signal(1.0 + i as f64)))]).render();
        let t = Instant::now();
        post(&mut stream, &mut reader, "/coreset", &body);
        miss_lat.push(t.elapsed().as_secs_f64());
    }
    let mut hit_lat = Vec::new();
    let hit_body = Json::obj(vec![("signal", signal_json(&bench_signal(1.0)))]).render();
    for _ in 0..hit_samples {
        let t = Instant::now();
        post(&mut stream, &mut reader, "/coreset", &hit_body);
        hit_lat.push(t.elapsed().as_secs_f64());
    }
    drop((stream, reader));
    shutdown(addr, handle);
    miss_lat.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    hit_lat.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let (miss_p50, hit_p50) = (percentile(&miss_lat, 0.5), percentile(&hit_lat, 0.5));

    let mut cache_table = Table::new(&["op", "samples", "p50", "speedup"]);
    cache_table.row(&[
        "coreset build (cache miss)".into(),
        format!("{miss_samples}"),
        format!("{:.3} ms", miss_p50 * 1e3),
        "x1.00".into(),
    ]);
    cache_table.row(&[
        "coreset lookup (cache hit)".into(),
        format!("{hit_samples}"),
        format!("{:.3} ms", hit_p50 * 1e3),
        format!("x{:.1}", miss_p50 / hit_p50.max(1e-12)),
    ]);
    cache_table.print("serve /coreset: LRU cache miss (full build) vs hit");
    let cache_rows = vec![
        Json::obj(vec![
            ("op", Json::str("coreset_build_miss")),
            ("samples", Json::int(miss_samples)),
            ("median_s", Json::num(miss_p50)),
        ]),
        Json::obj(vec![
            ("op", Json::str("coreset_cache_hit")),
            ("samples", Json::int(hit_samples)),
            ("median_s", Json::num(hit_p50)),
            ("speedup_vs_miss", Json::num(miss_p50 / hit_p50.max(1e-12))),
        ]),
    ];

    // ---- machine-readable evidence trail ---------------------------------
    let doc = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("provenance", Json::str("measured")),
        ("quick", Json::Bool(quick)),
        (
            "serve_case",
            Json::obj(vec![
                ("rows", Json::int(signal.rows())),
                ("cols", Json::int(signal.cols())),
                ("k", Json::int(8)),
                ("eps", Json::num(0.3)),
                ("server_threads", Json::int(8)),
                ("engine_threads", Json::int(4)),
            ]),
        ),
        ("serve_fitting_loss", Json::Arr(fit_rows)),
        ("coreset_cache", Json::Arr(cache_rows)),
    ]);
    match std::fs::write("BENCH_serve.json", doc.render()) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => println!("\ncould not write BENCH_serve.json: {e}"),
    }
}
