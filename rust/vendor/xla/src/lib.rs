//! Compile-only stub of the `xla` PJRT binding.
//!
//! The offline build environment has no registry access, so this crate
//! provides exactly the API surface `sigtree::runtime::pjrt` compiles
//! against: client construction, HLO-text parsing, compilation, and
//! literal round-trips. Every entry point that would touch PJRT returns
//! a descriptive runtime error — the `pjrt` feature therefore *compiles*
//! everywhere and *executes* nowhere until a real binding is swapped in.
//!
//! To run on a real PJRT client, replace this path dependency in
//! `rust/Cargo.toml` with a real `xla` binding (e.g. the xla-rs crate
//! family) or shadow it via `[patch]`; the sigtree runtime code is
//! identical either way.

use std::fmt;
use std::path::Path;

/// The error type mirrored from the real binding's surface (callers only
/// format it with `{:?}`).
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "xla stub: {what} unavailable — this offline build bundles a compile-only \
         stub; swap rust/vendor/xla for a real PJRT binding to execute"
    )))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Stub of the PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Stub of a device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_compile_and_error_descriptively() {
        let err = PjRtClient::cpu().err().expect("stub must not execute");
        assert!(format!("{err:?}").contains("stub"));
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let _ = comp; // constructible without PJRT
    }
}
