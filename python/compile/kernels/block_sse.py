"""Layer-1 Pallas kernel: batched opt₁ over rectangles ("block SSE").

Given the padded integral images (a zero row/column in front, so queries
need no boundary branches), each rectangle's statistics are four gathers
and a handful of VPU ops:

    opt₁(B) = Σy² − (Σy)² / |B|   (clamped at 0)

The kernel grid runs over rectangle panels; every instance keeps the full
padded integral images resident in VMEM (2 × 257×257×4 B ≈ 516 KiB — the
dominant VMEM cost, still far under budget) and processes
``RECT_PANEL`` rectangles with vectorized gathers. The
unaligned 257-side is the price of the query-friendly padding; DESIGN.md
§Hardware-Adaptation discusses the aligned-258 alternative.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RECT_PANEL = 128


def _block_sse_kernel(ii_y_ref, ii_y2_ref, rects_ref, o_ref):
    rects = rects_ref[...]
    r0 = rects[:, 0]
    r1 = rects[:, 1]
    c0 = rects[:, 2]
    c1 = rects[:, 3]
    ii_y = ii_y_ref[...]
    ii_y2 = ii_y2_ref[...]

    def q(ii):
        return ii[r1 + 1, c1 + 1] - ii[r0, c1 + 1] - ii[r1 + 1, c0] + ii[r0, c0]

    s = q(ii_y)
    sq = q(ii_y2)
    cnt = ((r1 - r0 + 1) * (c1 - c0 + 1)).astype(ii_y.dtype)
    cnt = jnp.maximum(cnt, 1)
    o_ref[...] = jnp.maximum(sq - s * s / cnt, 0.0)


def block_sse(
    ii_y_pad: jnp.ndarray, ii_y2_pad: jnp.ndarray, rects: jnp.ndarray
) -> jnp.ndarray:
    """Batched opt₁; ``rects`` is int32 [B, 4] inclusive (r0, r1, c0, c1),
    B a multiple of RECT_PANEL."""
    (b, four) = rects.shape
    assert four == 4
    assert b % RECT_PANEL == 0, b
    side = ii_y_pad.shape[0]
    return pl.pallas_call(
        _block_sse_kernel,
        grid=(b // RECT_PANEL,),
        in_specs=[
            pl.BlockSpec((side, side), lambda i: (0, 0)),
            pl.BlockSpec((side, side), lambda i: (0, 0)),
            pl.BlockSpec((RECT_PANEL, 4), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((RECT_PANEL,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), ii_y_pad.dtype),
        interpret=True,
    )(ii_y_pad, ii_y2_pad, rects)
