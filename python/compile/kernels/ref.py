"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops only. pytest (and hypothesis sweeps) assert
kernel == oracle to float tolerance; the AOT artifacts are lowered from
the *kernel* path, so the oracle is the single source of numerical truth.
"""

from __future__ import annotations

import jax.numpy as jnp


def prefix2d_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inclusive 2D prefix sums (integral images) of x and x².

    Returns (ii_y, ii_y2), each the same shape as ``x``:
    ``ii[r, c] = sum(x[:r+1, :c+1])``.
    """
    ii_y = jnp.cumsum(jnp.cumsum(x, axis=0), axis=1)
    ii_y2 = jnp.cumsum(jnp.cumsum(x * x, axis=0), axis=1)
    return ii_y, ii_y2


def pad_integral_ref(ii: jnp.ndarray) -> jnp.ndarray:
    """Prepend a zero row and column (the query-friendly layout)."""
    n, m = ii.shape
    out = jnp.zeros((n + 1, m + 1), dtype=ii.dtype)
    return out.at[1:, 1:].set(ii)


def block_sse_ref(
    ii_y_pad: jnp.ndarray, ii_y2_pad: jnp.ndarray, rects: jnp.ndarray
) -> jnp.ndarray:
    """Batched opt₁ over rectangles from padded integral images.

    ``rects`` is int32 [B, 4] with inclusive (r0, r1, c0, c1).
    opt₁ = Σy² − (Σy)²/count over each rectangle, clamped at 0.
    """
    r0, r1, c0, c1 = rects[:, 0], rects[:, 1], rects[:, 2], rects[:, 3]

    def q(ii):
        return (
            ii[r1 + 1, c1 + 1]
            - ii[r0, c1 + 1]
            - ii[r1 + 1, c0]
            + ii[r0, c0]
        )

    s = q(ii_y_pad)
    sq = q(ii_y2_pad)
    cnt = ((r1 - r0 + 1) * (c1 - c0 + 1)).astype(ii_y_pad.dtype)
    cnt = jnp.maximum(cnt, 1)
    return jnp.maximum(sq - s * s / cnt, 0.0)


def seg_loss_ref(signal: jnp.ndarray, rendered: jnp.ndarray) -> jnp.ndarray:
    """SSE between a signal tile and a rendered segmentation tile.

    Returns a [1] array (scalar losses round-trip more cleanly through
    the HLO text bridge as rank-1).
    """
    d = signal - rendered
    return jnp.sum(d * d).reshape((1,))
