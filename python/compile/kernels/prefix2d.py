"""Layer-1 Pallas kernel: tiled 2D inclusive prefix sums (integral images).

The paper's compute hot-spot is block-statistics evaluation: every opt₁
query is four gathers into integral images, so building the integral
images of y and y² IS the bulk numeric work per signal. On TPU the
natural schedule is two panel passes (the classic scan decomposition):

* pass 1 — grid over **row panels**: each instance holds a
  ``(ROWS_PER_PANEL, M)`` block in VMEM and computes the cumulative sum
  along the row axis-1 (independent per row, VPU-friendly);
* pass 2 — grid over **column panels**: each instance holds an
  ``(N, COLS_PER_PANEL)`` block and cumsums along axis 0.

VMEM footprint per instance: 32×256×4 B = 32 KiB (pass 1) / 256×32×4 B =
32 KiB (pass 2) — far under the ~16 MiB VMEM budget, leaving room for
double-buffering (see DESIGN.md §Perf). ``interpret=True`` everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Panel sizes — multiples of the 8×128 f32 TPU tile.
ROW_PANEL = 32
COL_PANEL = 32


def _row_scan_kernel(x_ref, o_ref):
    """Cumulative sum along axis 1 of one row panel."""
    o_ref[...] = jnp.cumsum(x_ref[...], axis=1)


def _col_scan_kernel(x_ref, o_ref):
    """Cumulative sum along axis 0 of one column panel."""
    o_ref[...] = jnp.cumsum(x_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=())
def _scan2d(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive 2D prefix sum of one array via the two panel passes."""
    n, m = x.shape
    assert n % ROW_PANEL == 0 and m % COL_PANEL == 0, (n, m)
    rowwise = pl.pallas_call(
        _row_scan_kernel,
        grid=(n // ROW_PANEL,),
        in_specs=[pl.BlockSpec((ROW_PANEL, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_PANEL, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x)
    return pl.pallas_call(
        _col_scan_kernel,
        grid=(m // COL_PANEL,),
        in_specs=[pl.BlockSpec((n, COL_PANEL), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, COL_PANEL), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(rowwise)


def prefix2d(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Integral images of (y, y²) — the Pallas counterpart of
    :func:`..ref.prefix2d_ref`."""
    return _scan2d(x), _scan2d(x * x)
