"""Layer-1 Pallas kernel: SSE between a signal tile and a rendered
segmentation tile.

A pure element-wise-plus-reduction kernel: the grid runs over row panels,
each instance reduces its panel to one partial sum; the final (tiny)
cross-panel sum happens in plain jnp. This is the canonical two-level
reduction a TPU implementation would use (panel partials in VMEM, final
combine on the scalar unit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_PANEL = 32


def _sse_panel_kernel(a_ref, b_ref, o_ref):
    d = a_ref[...] - b_ref[...]
    o_ref[...] = jnp.sum(d * d).reshape((1,))


def seg_loss(signal: jnp.ndarray, rendered: jnp.ndarray) -> jnp.ndarray:
    """Total SSE as a [1] array (rank-1 round-trips the HLO text bridge
    more cleanly than rank-0)."""
    n, m = signal.shape
    assert signal.shape == rendered.shape
    assert n % ROW_PANEL == 0, n
    panels = n // ROW_PANEL
    partials = pl.pallas_call(
        _sse_panel_kernel,
        grid=(panels,),
        in_specs=[
            pl.BlockSpec((ROW_PANEL, m), lambda i: (i, 0)),
            pl.BlockSpec((ROW_PANEL, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((panels,), signal.dtype),
        interpret=True,
    )(signal, rendered)
    return jnp.sum(partials).reshape((1,))
