"""Layer-2 JAX compute graph — composes the Layer-1 Pallas kernels into
the functions the Rust coordinator executes through PJRT.

This module is build-time only: `aot.py` lowers the jitted functions to
HLO text once, and the Rust runtime (`rust/src/runtime/`) loads and runs
the artifacts. Python never appears on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import block_sse as _block_sse
from .kernels import prefix2d as _prefix2d
from .kernels import seg_loss as _seg_loss

# Shapes baked into the AOT artifacts (mirrored by rust/src/runtime/mod.rs).
TILE = 256
RECT_BATCH = 1024


def prefix2d_model(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(TILE, TILE) signal tile → inclusive integral images of (y, y²)."""
    return _prefix2d.prefix2d(x)


def pad_integral(ii: jnp.ndarray) -> jnp.ndarray:
    """Prepend a zero row and column: (T, T) → (T+1, T+1)."""
    n, m = ii.shape
    return jnp.zeros((n + 1, m + 1), ii.dtype).at[1:, 1:].set(ii)


def block_sse_model(
    ii_y_pad: jnp.ndarray, ii_y2_pad: jnp.ndarray, rects: jnp.ndarray
) -> jnp.ndarray:
    """(T+1, T+1) padded integral images + int32 [B, 4] rects → [B] opt₁."""
    return _block_sse.block_sse(ii_y_pad, ii_y2_pad, rects)


def seg_loss_model(signal: jnp.ndarray, rendered: jnp.ndarray) -> jnp.ndarray:
    """Two (TILE, TILE) tiles → [1] total SSE."""
    return _seg_loss.seg_loss(signal, rendered)


def example_args() -> dict[str, tuple]:
    """Example (shape-defining) arguments per artifact name."""
    f32 = jnp.float32
    i32 = jnp.int32
    tile = jax.ShapeDtypeStruct((TILE, TILE), f32)
    padded = jax.ShapeDtypeStruct((TILE + 1, TILE + 1), f32)
    rects = jax.ShapeDtypeStruct((RECT_BATCH, 4), i32)
    return {
        "prefix2d": (prefix2d_model, (tile,)),
        "block_sse": (block_sse_model, (padded, padded, rects)),
        "seg_loss": (seg_loss_model, (tile, tile)),
    }
