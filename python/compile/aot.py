"""AOT compilation: lower the Layer-2 JAX models (which embed the Layer-1
Pallas kernels) to HLO **text** artifacts for the Rust PJRT runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: pathlib.Path) -> list[pathlib.Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for name, (fn, args) in model.example_args().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
        written.append(path)
    return written


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir",
        type=pathlib.Path,
        default=pathlib.Path(__file__).resolve().parents[2] / "artifacts",
    )
    args = parser.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
