"""Kernel vs. pure-jnp oracle — the core L1 correctness signal.

Fixed-shape checks at the AOT shapes plus hypothesis sweeps over panel-
aligned shapes and value distributions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_sse, prefix2d, ref, seg_loss

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- prefix2d


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_prefix2d_matches_ref_at_aot_shape(seed):
    x = jnp.asarray(rand((256, 256), seed))
    got_y, got_y2 = prefix2d.prefix2d(x)
    ref_y, ref_y2 = ref.prefix2d_ref(x)
    np.testing.assert_allclose(got_y, ref_y, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(got_y2, ref_y2, rtol=1e-5, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_prefix2d_hypothesis_shapes(rows, cols, seed, scale):
    n, m = rows * prefix2d.ROW_PANEL, cols * prefix2d.COL_PANEL
    x = jnp.asarray(rand((n, m), seed, scale))
    got_y, got_y2 = prefix2d.prefix2d(x)
    ref_y, ref_y2 = ref.prefix2d_ref(x)
    np.testing.assert_allclose(got_y, ref_y, rtol=1e-4, atol=1e-2 * scale)
    np.testing.assert_allclose(got_y2, ref_y2, rtol=1e-4, atol=1e-1 * scale**2)


def test_prefix2d_constant_input():
    x = jnp.ones((64, 64), jnp.float32) * 2.0
    got_y, got_y2 = prefix2d.prefix2d(x)
    # ii[r, c] = 2 * (r+1) * (c+1); ii2 = 4 * (r+1) * (c+1)
    r, c = 10, 20
    assert got_y[r, c] == pytest.approx(2.0 * 11 * 21)
    assert got_y2[r, c] == pytest.approx(4.0 * 11 * 21)


# ---------------------------------------------------------------- block_sse


def _rects(batch, side, seed):
    rng = np.random.default_rng(seed)
    r0 = rng.integers(0, side, batch)
    r1 = rng.integers(r0, side)
    c0 = rng.integers(0, side, batch)
    c1 = rng.integers(c0, side)
    return jnp.asarray(np.stack([r0, r1, c0, c1], axis=1).astype(np.int32))


@pytest.mark.parametrize("seed", [3, 4])
def test_block_sse_matches_ref(seed):
    x = jnp.asarray(rand((256, 256), seed))
    ii_y, ii_y2 = ref.prefix2d_ref(x)
    p_y, p_y2 = ref.pad_integral_ref(ii_y), ref.pad_integral_ref(ii_y2)
    rects = _rects(1024, 256, seed)
    got = block_sse.block_sse(p_y, p_y2, rects)
    want = ref.block_sse_ref(p_y, p_y2, rects)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_block_sse_constant_blocks_are_zero():
    x = jnp.full((256, 256), 3.0, jnp.float32)
    ii_y, ii_y2 = ref.prefix2d_ref(x)
    p_y, p_y2 = ref.pad_integral_ref(ii_y), ref.pad_integral_ref(ii_y2)
    rects = _rects(128, 256, 0)
    got = block_sse.block_sse(p_y, p_y2, rects)
    # f32 cancellation noise scales with block magnitude; stay loose.
    assert np.all(np.asarray(got) < 1.0)


def test_block_sse_against_direct_variance():
    """End-to-end: kernel opt₁ equals the numpy variance of the block."""
    x_np = rand((256, 256), 7)
    x = jnp.asarray(x_np)
    ii_y, ii_y2 = prefix2d.prefix2d(x)
    p_y = ref.pad_integral_ref(ii_y)
    p_y2 = ref.pad_integral_ref(ii_y2)
    rects_np = np.asarray(_rects(128, 256, 8))
    got = np.asarray(block_sse.block_sse(p_y, p_y2, jnp.asarray(rects_np)))
    for i in range(0, 128, 17):
        r0, r1, c0, c1 = rects_np[i]
        blk = x_np[r0 : r1 + 1, c0 : c1 + 1].astype(np.float64)
        want = float(((blk - blk.mean()) ** 2).sum())
        assert got[i] == pytest.approx(want, rel=5e-2, abs=5e-2), i


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.5, 2.0, 8.0]))
def test_block_sse_hypothesis(seed, scale):
    x = jnp.asarray(rand((128, 128), seed, scale))
    ii_y, ii_y2 = ref.prefix2d_ref(x)
    p_y, p_y2 = ref.pad_integral_ref(ii_y), ref.pad_integral_ref(ii_y2)
    rects = _rects(block_sse.RECT_PANEL, 128, seed)
    got = block_sse.block_sse(p_y, p_y2, rects)
    want = ref.block_sse_ref(p_y, p_y2, rects)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2 * scale**2)


# ----------------------------------------------------------------- seg_loss


@pytest.mark.parametrize("seed", [5, 6])
def test_seg_loss_matches_ref(seed):
    a = jnp.asarray(rand((256, 256), seed))
    b = jnp.asarray(rand((256, 256), seed + 100))
    got = seg_loss.seg_loss(a, b)
    want = ref.seg_loss_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_seg_loss_zero_for_identical():
    a = jnp.asarray(rand((64, 64), 9))
    assert float(seg_loss.seg_loss(a, a)[0]) == 0.0


@settings(max_examples=8, deadline=None)
@given(
    panels=st.integers(1, 6),
    cols=st.sampled_from([32, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_seg_loss_hypothesis(panels, cols, seed):
    n = panels * seg_loss.ROW_PANEL
    a = jnp.asarray(rand((n, cols), seed))
    b = jnp.asarray(rand((n, cols), seed ^ 0xFFFF))
    got = float(seg_loss.seg_loss(a, b)[0])
    want = float(ref.seg_loss_ref(a, b)[0])
    assert got == pytest.approx(want, rel=1e-4)
