"""L2 model + AOT checks: shapes, HLO structure, artifact generation."""

from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_example_args_cover_all_artifacts():
    names = set(model.example_args().keys())
    assert names == {"prefix2d", "block_sse", "seg_loss"}


def test_model_shapes():
    x = jnp.zeros((model.TILE, model.TILE), jnp.float32)
    ii_y, ii_y2 = model.prefix2d_model(x)
    assert ii_y.shape == (model.TILE, model.TILE)
    assert ii_y2.shape == (model.TILE, model.TILE)
    p = model.pad_integral(ii_y)
    assert p.shape == (model.TILE + 1, model.TILE + 1)
    rects = jnp.zeros((model.RECT_BATCH, 4), jnp.int32)
    out = model.block_sse_model(p, p, rects)
    assert out.shape == (model.RECT_BATCH,)
    loss = model.seg_loss_model(x, x)
    assert loss.shape == (1,)


def test_pad_integral_matches_ref():
    rng = np.random.default_rng(0)
    ii = jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32))
    np.testing.assert_array_equal(model.pad_integral(ii), ref.pad_integral_ref(ii))


def test_hlo_text_lowering_roundtrips():
    """The HLO text must parse-visibly contain an entry computation and
    no serialized-proto artifacts; cheap structural smoke for the bridge."""
    fn, args = model.example_args()["seg_loss"]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[256,256]" in text
    assert len(text) > 200


def test_no_quadratic_window_reduction_in_prefix2d():
    """Perf guard (DESIGN.md §Perf L2): the lowered prefix2d must not
    contain a reduce-window over the full tile (the O(N²) naive windowed
    sum); cumulative sums lower to iota/pad/while/reduce-window with
    *small* windows or scan loops, never a [256,256]-window reduce."""
    fn, args = model.example_args()["prefix2d"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "window={size=256x256" not in text.replace(" ", "")


def test_build_all_writes_artifacts(tmp_path: pathlib.Path):
    written = aot.build_all(tmp_path)
    assert len(written) == 3
    for path in written:
        assert path.exists()
        head = path.read_text()[:2000]
        assert "HloModule" in head


def test_artifact_numerics_via_jax_reexecution():
    """Execute the lowered computation through jax's own runtime and
    compare against the oracle — validates the exact graph that is
    exported (the Rust side re-checks through PJRT in its tests)."""
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((model.TILE, model.TILE)).astype(np.float32))
    got_y, got_y2 = jax.jit(model.prefix2d_model)(x)
    ref_y, ref_y2 = ref.prefix2d_ref(x)
    np.testing.assert_allclose(got_y, ref_y, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(got_y2, ref_y2, rtol=1e-5, atol=1e-2)


def test_block_sse_model_numerics():
    rng = np.random.default_rng(43)
    x = jnp.asarray(rng.standard_normal((model.TILE, model.TILE)).astype(np.float32))
    ii_y, ii_y2 = ref.prefix2d_ref(x)
    p_y, p_y2 = ref.pad_integral_ref(ii_y), ref.pad_integral_ref(ii_y2)
    r0 = rng.integers(0, model.TILE, model.RECT_BATCH)
    r1 = rng.integers(r0, model.TILE)
    c0 = rng.integers(0, model.TILE, model.RECT_BATCH)
    c1 = rng.integers(c0, model.TILE)
    rects = jnp.asarray(np.stack([r0, r1, c0, c1], 1).astype(np.int32))
    got = jax.jit(model.block_sse_model)(p_y, p_y2, rects)
    want = ref.block_sse_ref(p_y, p_y2, rects)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("name", ["prefix2d", "block_sse", "seg_loss"])
def test_each_artifact_lowers(name):
    fn, args = model.example_args()[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
