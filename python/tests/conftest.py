"""Make the `compile` package importable when pytest runs from the repo
root (the Makefile runs it from python/; both must work), and keep
offline runs green: modules whose optional deps (jax / hypothesis) are
absent are excluded from collection instead of erroring — the JAX/Pallas
kernels are an optional AOT path; the Rust native backend is the
offline default."""
import importlib.util
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _missing(module: str) -> bool:
    return importlib.util.find_spec(module) is None


collect_ignore = []
if _missing("jax"):
    # Everything here exercises the JAX kernels/AOT pipeline.
    collect_ignore += ["test_kernels.py", "test_model_aot.py"]
if _missing("hypothesis"):
    # The kernel sweeps are hypothesis-driven.
    collect_ignore += ["test_kernels.py"]
