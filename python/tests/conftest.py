"""Make the `compile` package importable when pytest runs from the repo
root (the Makefile runs it from python/; both must work)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
