#!/usr/bin/env bash
# Perf regression gate over bench_runtime's machine-readable output.
#
#   scripts/bench_gate.sh               compare rust/BENCH_runtime.json
#                                       (current run) against the committed
#                                       BENCH_runtime.json baseline
#   scripts/bench_gate.sh --rebaseline  promote the current run to be the
#                                       committed baseline
#
# Policy:
#   * baseline provenance "bootstrap" (the committed placeholder with null
#     medians): schema check only, always exit 0 — there is nothing honest
#     to gate against until someone runs the bench on real hardware and
#     promotes it with --rebaseline.
#   * baseline provenance "measured": hard-fail when any row's median_s
#     regresses by more than 15% vs the baseline row with the same
#     identity (section + op + impl/mode + threads). Rows present on only
#     one side (e.g. a --quick run vs a full baseline) are skipped with a
#     note, never failed.
#   * BENCH_GATE_ADVISORY=1 downgrades a failing comparison to a warning
#     (for shared CI runners whose timings are too noisy to hard-gate).
set -euo pipefail
cd "$(dirname "$0")/.."

# cargo bench runs the harness with cwd = the package root (rust/), so
# the current run lands there; the committed baseline sits at the
# workspace root.
BASELINE="BENCH_runtime.json"
CURRENT="rust/BENCH_runtime.json"

if [ "${1:-}" = "--rebaseline" ]; then
    if [ ! -f "$CURRENT" ]; then
        echo "bench_gate: no current run at rust/BENCH_runtime.json — run \`cargo bench --bench bench_runtime\` first" >&2
        exit 1
    fi
    cp "$CURRENT" "$BASELINE"
    echo "bench_gate: promoted $CURRENT -> $BASELINE (commit it to update the baseline)"
    exit 0
fi

THRESHOLD="${BENCH_GATE_THRESHOLD:-1.15}" \
ADVISORY="${BENCH_GATE_ADVISORY:-0}" \
python3 - "$BASELINE" "$CURRENT" <<'PY'
import json, os, sys

baseline_path, current_path = sys.argv[1], sys.argv[2]
threshold = float(os.environ["THRESHOLD"])
advisory = os.environ["ADVISORY"] == "1"

REQUIRED = [
    "bench", "provenance", "quick", "acceptance_case", "backends",
    "kernels", "blocked_speedup", "prefix_build", "thread_scaling",
    "engine_reuse", "alloc_profile", "incremental_update",
]
# Fields that are measurements, not row identity.
METRICS = {
    "median_s", "p90_s", "speedup_vs_1t", "speedup_vs_full",
    "speedup_vs_scalar", "speedup_vs_native", "batches_per_s",
    "native_median_s", "blocked_median_s", "allocs_total", "stats_allocs",
    "allocs_per_shard", "kib_per_shard", "blocks",
}

def load(path, who):
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"bench_gate: {who} file {path} not found", file=sys.stderr)
        sys.exit(0 if advisory else 1)
    missing = [k for k in REQUIRED if k not in doc]
    if missing:
        print(f"bench_gate: {who} {path} is missing keys {missing}", file=sys.stderr)
        sys.exit(0 if advisory else 1)
    return doc

def rows(doc):
    out = {}
    for section, val in doc.items():
        if not isinstance(val, list):
            continue
        for row in val:
            if not isinstance(row, dict) or "median_s" not in row:
                continue
            ident = (section,) + tuple(
                f"{k}={row[k]}" for k in sorted(row) if k not in METRICS
            )
            out[ident] = row["median_s"]
    return out

base = load(baseline_path, "baseline")
cur = load(current_path, "current")

if cur.get("provenance") != "measured":
    print(f"bench_gate: current run has provenance {cur.get('provenance')!r}, expected 'measured'",
          file=sys.stderr)
    sys.exit(0 if advisory else 1)

if base.get("provenance") == "bootstrap":
    print("bench_gate: baseline is the bootstrap placeholder (null medians) — "
          "schema OK, nothing to gate against. Promote a measured run with "
          "`scripts/bench_gate.sh --rebaseline`.")
    sys.exit(0)

base_rows, cur_rows = rows(base), rows(cur)
failures, compared, skipped = [], 0, 0
for ident, b in sorted(base_rows.items()):
    c = cur_rows.get(ident)
    if c is None or b is None or not (b > 0):
        skipped += 1
        continue
    compared += 1
    ratio = c / b
    tag = " ".join(ident)
    if ratio > threshold:
        failures.append(f"  {tag}: {b:.6f}s -> {c:.6f}s (x{ratio:.2f} > x{threshold:.2f})")
    else:
        print(f"bench_gate: ok   {tag}: x{ratio:.2f}")
only_current = sum(1 for k in cur_rows if k not in base_rows)
if skipped or only_current:
    print(f"bench_gate: skipped {skipped} baseline row(s) without a comparable "
          f"current row; {only_current} current row(s) not in baseline")
print(f"bench_gate: compared {compared} row(s) against {baseline_path}")
if failures:
    print(f"bench_gate: median regression > {(threshold - 1) * 100:.0f}% on:", file=sys.stderr)
    print("\n".join(failures), file=sys.stderr)
    if advisory:
        print("bench_gate: BENCH_GATE_ADVISORY=1 — reporting only, not failing")
        sys.exit(0)
    sys.exit(1)
print("bench_gate: OK")
PY
