#!/usr/bin/env bash
# Perf regression gate over the benches' machine-readable output.
#
#   scripts/bench_gate.sh               compare every current run
#                                       (rust/BENCH_<name>.json) against its
#                                       committed baseline (BENCH_<name>.json)
#   scripts/bench_gate.sh --pair NAME   gate one pair only
#                                       (runtime | serve | forest)
#   scripts/bench_gate.sh --rebaseline  promote every current run present to
#                                       be the committed baseline
#
# Gated pairs:
#   runtime  BENCH_runtime.json  <- cargo bench --bench bench_runtime
#   serve    BENCH_serve.json    <- cargo bench --bench bench_serve
#   forest   BENCH_forest.json   <- cargo bench --bench bench_forest
#
# Policy (per pair):
#   * baseline provenance "bootstrap" (a committed placeholder with null
#     medians): schema check only, always exit 0 — there is nothing honest
#     to gate against until someone runs the bench on real hardware and
#     promotes it with --rebaseline.
#   * baseline provenance "measured": hard-fail when any row's median_s
#     regresses by more than 15% vs the baseline row with the same
#     identity (section + op + impl/mode/clients + threads). Rows present
#     on only one side (e.g. a --quick run vs a full baseline) are skipped
#     with a note, never failed. A row present in the current run whose
#     median_s is null (the bench emitted the row but measured nothing) is
#     also skipped, with an explicit "null median_s" note — it is NOT a
#     comparison and NOT the same as a missing row.
#   * BENCH_GATE_ADVISORY=1 downgrades a failing comparison to a warning
#     (for shared CI runners whose timings are too noisy to hard-gate).
#
# Test hooks (used by scripts/test_bench_gate.sh to exercise the
# comparator against synthetic JSON without touching the real files):
#   BENCH_GATE_BASELINE / BENCH_GATE_CURRENT  override the file pair
#   BENCH_GATE_REQUIRED                       comma-separated schema keys
set -euo pipefail
cd "$(dirname "$0")/.."

RUNTIME_REQUIRED="bench,provenance,quick,acceptance_case,backends,kernels,blocked_speedup,prefix_build,thread_scaling,engine_reuse,alloc_profile,incremental_update"
SERVE_REQUIRED="bench,provenance,quick,serve_case,serve_fitting_loss,coreset_cache"
FOREST_REQUIRED="bench,provenance,quick,forest_case,forest_sweep"

# name|baseline|current|required-keys
PAIRS=(
    "runtime|BENCH_runtime.json|rust/BENCH_runtime.json|$RUNTIME_REQUIRED"
    "serve|BENCH_serve.json|rust/BENCH_serve.json|$SERVE_REQUIRED"
    "forest|BENCH_forest.json|rust/BENCH_forest.json|$FOREST_REQUIRED"
)

ONLY_PAIR=""
REBASELINE=0
while [ $# -gt 0 ]; do
    case "$1" in
        --rebaseline) REBASELINE=1 ;;
        --pair)
            shift
            ONLY_PAIR="${1:-}"
            ;;
        *)
            echo "bench_gate: unknown argument '$1' (usage: bench_gate.sh [--pair NAME] [--rebaseline])" >&2
            exit 2
            ;;
    esac
    shift
done

# Synthetic-pair override for the self-test: one pair, caller-supplied
# files and schema.
if [ -n "${BENCH_GATE_BASELINE:-}" ] || [ -n "${BENCH_GATE_CURRENT:-}" ]; then
    PAIRS=("synthetic|${BENCH_GATE_BASELINE:?}|${BENCH_GATE_CURRENT:?}|${BENCH_GATE_REQUIRED:-bench,provenance}")
fi

if [ "$REBASELINE" = 1 ]; then
    promoted=0
    for pair in "${PAIRS[@]}"; do
        IFS='|' read -r name baseline current _required <<<"$pair"
        [ -n "$ONLY_PAIR" ] && [ "$name" != "$ONLY_PAIR" ] && continue
        if [ -f "$current" ]; then
            cp "$current" "$baseline"
            echo "bench_gate: promoted $current -> $baseline (commit it to update the baseline)"
            promoted=$((promoted + 1))
        else
            echo "bench_gate: no current run at $current — skipping the $name pair"
        fi
    done
    if [ "$promoted" = 0 ]; then
        echo "bench_gate: nothing to promote — run the benches first (e.g. \`cargo bench --bench bench_runtime\`)" >&2
        exit 1
    fi
    exit 0
fi

status=0
for pair in "${PAIRS[@]}"; do
    IFS='|' read -r name baseline current required <<<"$pair"
    [ -n "$ONLY_PAIR" ] && [ "$name" != "$ONLY_PAIR" ] && continue
    echo "bench_gate: === pair '$name' ($current vs $baseline) ==="
    if THRESHOLD="${BENCH_GATE_THRESHOLD:-1.15}" \
       ADVISORY="${BENCH_GATE_ADVISORY:-0}" \
       REQUIRED_KEYS="$required" \
       python3 - "$baseline" "$current" <<'PY'
import json, os, sys

baseline_path, current_path = sys.argv[1], sys.argv[2]
threshold = float(os.environ["THRESHOLD"])
advisory = os.environ["ADVISORY"] == "1"

REQUIRED = [k for k in os.environ["REQUIRED_KEYS"].split(",") if k]
# Fields that are measurements, not row identity.
METRICS = {
    "median_s", "p90_s", "p99_s", "rps", "requests", "samples",
    "speedup_vs_1t", "speedup_vs_full", "speedup_vs_scalar",
    "speedup_vs_native", "speedup_vs_miss", "batches_per_s",
    "native_median_s", "blocked_median_s", "allocs_total", "stats_allocs",
    "allocs_per_shard", "kib_per_shard", "blocks",
    # forest sweep: τ is derived from the measured compression size and
    # the SSE columns are quality measurements — none of them identity.
    "full_median_s", "test_sse_full", "test_sse_coreset", "sse_gap_pct",
    "tau",
}

def load(path, who):
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"bench_gate: {who} file {path} not found", file=sys.stderr)
        sys.exit(0 if advisory else 1)
    missing = [k for k in REQUIRED if k not in doc]
    if missing:
        print(f"bench_gate: {who} {path} is missing keys {missing}", file=sys.stderr)
        sys.exit(0 if advisory else 1)
    return doc

def rows(doc):
    out = {}
    for section, val in doc.items():
        if not isinstance(val, list):
            continue
        for row in val:
            if not isinstance(row, dict) or "median_s" not in row:
                continue
            ident = (section,) + tuple(
                f"{k}={row[k]}" for k in sorted(row) if k not in METRICS
            )
            out[ident] = row["median_s"]
    return out

base = load(baseline_path, "baseline")
cur = load(current_path, "current")

if cur.get("provenance") != "measured":
    print(f"bench_gate: current run has provenance {cur.get('provenance')!r}, expected 'measured'",
          file=sys.stderr)
    sys.exit(0 if advisory else 1)

if base.get("provenance") == "bootstrap":
    print("bench_gate: baseline is the bootstrap placeholder (null medians) — "
          "schema OK, nothing to gate against. Promote a measured run with "
          "`scripts/bench_gate.sh --rebaseline`.")
    sys.exit(0)

base_rows, cur_rows = rows(base), rows(cur)
failures, compared = [], 0
missing_current = null_current = bad_baseline = 0
for ident, b in sorted(base_rows.items()):
    tag = " ".join(ident)
    if ident not in cur_rows:
        # e.g. a --quick current run vs a full baseline: the row was
        # never emitted this run.
        missing_current += 1
        continue
    c = cur_rows[ident]
    if c is None:
        # The current run emitted this row but measured nothing (null
        # median_s). Distinct from a missing row: the bench reached the
        # row and produced no timing, which deserves an explicit note —
        # silently lumping it into the generic skip count hid real
        # sampler failures.
        null_current += 1
        print(f"bench_gate: note {tag}: null median_s in current run — skipped")
        continue
    if b is None or not (b > 0):
        bad_baseline += 1
        continue
    compared += 1
    ratio = c / b
    if ratio > threshold:
        failures.append(f"  {tag}: {b:.6f}s -> {c:.6f}s (x{ratio:.2f} > x{threshold:.2f})")
    else:
        print(f"bench_gate: ok   {tag}: x{ratio:.2f}")
only_current = sum(1 for k in cur_rows if k not in base_rows)
if missing_current or null_current or bad_baseline or only_current:
    print(f"bench_gate: skipped {missing_current} baseline row(s) absent from the current run, "
          f"{null_current} current row(s) with null median_s, "
          f"{bad_baseline} baseline row(s) without a usable median; "
          f"{only_current} current row(s) not in baseline")
print(f"bench_gate: compared {compared} row(s) against {baseline_path}")
if failures:
    print(f"bench_gate: median regression > {(threshold - 1) * 100:.0f}% on:", file=sys.stderr)
    print("\n".join(failures), file=sys.stderr)
    if advisory:
        print("bench_gate: BENCH_GATE_ADVISORY=1 — reporting only, not failing")
        sys.exit(0)
    sys.exit(1)
print("bench_gate: OK")
PY
    then
        :
    else
        status=1
    fi
done
exit "$status"
