#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): build + test + example smoke, all on
# the default (no-pjrt) feature set so it runs offline with zero
# external dependencies.
set -euo pipefail
cd "$(dirname "$0")/.."

# Style gates first: formatting and lints are cheap and fail fast.
cargo fmt --check
cargo clippy --all-targets -- -D warnings

cargo build --release

# Static-analysis gate: the crate's own linter (panic-freedom,
# determinism, unsafe hygiene, error discipline, shim delegation) must
# pass on the tree. Hard gate — non-zero exit on any finding; the
# byte-stable JSON report lands in lint.json (archived by ci.yml).
cargo run --release -- lint --json lint.json

cargo test -q
# Merge-tree acceptance suite, named explicitly: bit-identity of
# MergeTree::full() with construct_sharded_exec, dirty-leaf-only
# updates, the ε-audit after a seeded mutation sequence, and the
# streaming facade (redundant with `cargo test` above, but this is the
# tentpole's contract — a rename or filter must not silently drop it).
cargo test -q --test integration_merge_tree
cargo build --examples

# Docs gate: deprecation notes and intra-doc links (the engine migration
# leans on both) must stay valid.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# The PJRT path must stay compile-clean against the bundled stub.
cargo check --features pjrt

# Engine-path smoke: the rewired CLI front door (one Engine per
# subcommand, unknown flags rejected, sharded build on the pool).
cargo run --release -- coreset --k 5 --eps 0.4 --threads 2

# Multi-thread smoke: exercises the engine pool paths (sharded build,
# pool-built prefix stats) plus the kernel parity checks.
cargo run --release -- runtime --backend native --threads 2

# Cache-blocked backend smoke: the same parity checks end-to-end through
# the blocked kernel + blocked prefix-stats fill (non-divisor block
# width on purpose — exercises the ragged-tail lanes).
cargo run --release -- runtime --backend blocked --threads 2 --block-size 48

# Incremental-update smoke: seeded tile edits through an EditSession —
# fails non-zero if the updated coreset's weight drifts from a
# from-scratch rebuild of the mutated signal.
cargo run --release -- update --n 256 --m 256 --k 16 --eps 0.3 --edits 4 --tile 64 --threads 2

# Empirical ε-guarantee audit (fixed seed): adversarial query families +
# optimal-tree-transfer checks; exits non-zero on any violated gate and
# leaves the machine-readable evidence trail in audit.json (archived as
# a CI artifact by ci.yml).
cargo run --release -- audit --k 5 --eps 0.5 --cases 25 --seed 7 --json audit.json

# Serve smoke: boot the daemon on an ephemeral port (written to a port
# file after bind), drive it over raw /dev/tcp — no curl dependency —
# and require the cache-hit path plus a clean drain. The full
# bit-identity and hostile-input coverage lives in
# tests/integration_serve.rs; this proves the shipped binary serves.
SERVE_PORT_FILE="$(mktemp)"
cargo run --release -- serve --k 4 --eps 0.4 --threads 2 --serve-threads 2 \
    --port 0 --port-file "$SERVE_PORT_FILE" &
SERVE_PID=$!
for _ in $(seq 1 100); do
    [ -s "$SERVE_PORT_FILE" ] && break
    sleep 0.1
done
[ -s "$SERVE_PORT_FILE" ] || { echo "serve smoke: no port file" >&2; kill "$SERVE_PID"; exit 1; }
SERVE_PORT="$(cat "$SERVE_PORT_FILE")"
serve_req() { # METHOD PATH BODY — prints status line + body to stdout
    local method="$1" path="$2" body="$3"
    exec 3<>"/dev/tcp/127.0.0.1/$SERVE_PORT"
    printf '%s %s HTTP/1.1\r\nContent-Length: %s\r\nConnection: close\r\n\r\n%s' \
        "$method" "$path" "${#body}" "$body" >&3
    cat <&3
    exec 3>&- 3<&-
}
SERVE_SIG='{"signal":{"rows":4,"cols":4,"values":[0,1,2,3,1,2,3,4,2,3,4,5,3,4,5,6]}}'
serve_req GET /healthz "" | grep -q '"ok": true' || { echo "serve smoke: healthz" >&2; exit 1; }
serve_req POST /coreset "$SERVE_SIG" | grep -q '"cached": false' \
    || { echo "serve smoke: first /coreset should be a cache miss" >&2; exit 1; }
serve_req POST /coreset "$SERVE_SIG" | grep -q '"cached": true' \
    || { echo "serve smoke: second /coreset should be a cache hit" >&2; exit 1; }
serve_req GET /stats "" | grep -q '"hits": 1' \
    || { echo "serve smoke: stats should count one cache hit" >&2; exit 1; }
serve_req POST /shutdown "" | grep -q '"draining": true' \
    || { echo "serve smoke: shutdown" >&2; exit 1; }
wait "$SERVE_PID" || { echo "serve smoke: daemon exited non-zero" >&2; exit 1; }
rm -f "$SERVE_PORT_FILE"
echo "serve smoke: OK"

# Perf regression gate: quick bench passes (reduced sizes/iterations,
# shapes embedded in row identities so quick rows never gate against
# full-run baseline rows), then hard-gate medians against the committed
# baselines — BENCH_runtime.json, BENCH_serve.json and BENCH_forest.json
# (>15% median slowdown fails; a bootstrap baseline with null medians is
# schema-checked only — the forest baseline starts life as one). The
# gate's own comparator logic is exercised first against synthetic
# fixtures — pure bash/python3, runs in seconds.
./scripts/test_bench_gate.sh
cargo bench --bench bench_runtime -- --quick
cargo bench --bench bench_serve -- --quick
cargo bench --bench bench_forest -- --quick
./scripts/bench_gate.sh

echo "verify.sh: OK"
