#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): build + test + example smoke, all on
# the default (no-pjrt) feature set so it runs offline with zero
# external dependencies.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo build --examples

echo "verify.sh: OK"
