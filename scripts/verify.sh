#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): build + test + example smoke, all on
# the default (no-pjrt) feature set so it runs offline with zero
# external dependencies.
set -euo pipefail
cd "$(dirname "$0")/.."

# Style gates first: formatting and lints are cheap and fail fast.
cargo fmt --check
cargo clippy --all-targets -- -D warnings

cargo build --release

# Static-analysis gate: the crate's own linter (panic-freedom,
# determinism, unsafe hygiene, error discipline, shim delegation) must
# pass on the tree. Hard gate — non-zero exit on any finding; the
# byte-stable JSON report lands in lint.json (archived by ci.yml).
cargo run --release -- lint --json lint.json

cargo test -q
# Merge-tree acceptance suite, named explicitly: bit-identity of
# MergeTree::full() with construct_sharded_exec, dirty-leaf-only
# updates, the ε-audit after a seeded mutation sequence, and the
# streaming facade (redundant with `cargo test` above, but this is the
# tentpole's contract — a rename or filter must not silently drop it).
cargo test -q --test integration_merge_tree
cargo build --examples

# Docs gate: deprecation notes and intra-doc links (the engine migration
# leans on both) must stay valid.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# The PJRT path must stay compile-clean against the bundled stub.
cargo check --features pjrt

# Engine-path smoke: the rewired CLI front door (one Engine per
# subcommand, unknown flags rejected, sharded build on the pool).
cargo run --release -- coreset --k 5 --eps 0.4 --threads 2

# Multi-thread smoke: exercises the engine pool paths (sharded build,
# pool-built prefix stats) plus the kernel parity checks.
cargo run --release -- runtime --backend native --threads 2

# Cache-blocked backend smoke: the same parity checks end-to-end through
# the blocked kernel + blocked prefix-stats fill (non-divisor block
# width on purpose — exercises the ragged-tail lanes).
cargo run --release -- runtime --backend blocked --threads 2 --block-size 48

# Incremental-update smoke: seeded tile edits through an EditSession —
# fails non-zero if the updated coreset's weight drifts from a
# from-scratch rebuild of the mutated signal.
cargo run --release -- update --n 256 --m 256 --k 16 --eps 0.3 --edits 4 --tile 64 --threads 2

# Empirical ε-guarantee audit (fixed seed): adversarial query families +
# optimal-tree-transfer checks; exits non-zero on any violated gate and
# leaves the machine-readable evidence trail in audit.json (archived as
# a CI artifact by ci.yml).
cargo run --release -- audit --k 5 --eps 0.5 --cases 25 --seed 7 --json audit.json

# Perf regression gate: a quick bench pass (reduced sizes/iterations,
# sizes embedded in row identities so quick rows never gate against
# full-run baseline rows), then hard-gate medians against the committed
# BENCH_runtime.json baseline (>15% median slowdown fails; a bootstrap
# baseline with null medians is schema-checked only).
cargo bench --bench bench_runtime -- --quick
./scripts/bench_gate.sh

echo "verify.sh: OK"
