#!/usr/bin/env bash
# Self-test for scripts/bench_gate.sh: drives the real gate script
# against synthetic baseline/current JSON (via the BENCH_GATE_BASELINE /
# BENCH_GATE_CURRENT test hooks) and asserts exit codes and output.
# Pure bash + python3 — runs anywhere, no Rust toolchain needed.
#
# Includes the regression test for the null-median_s bugfix: a current
# row that is PRESENT but carries `"median_s": null` must be skipped
# with an explicit "null median_s" note, not silently folded into the
# generic "row(s) absent from the current run" count.
set -euo pipefail
cd "$(dirname "$0")/.."

GATE="scripts/bench_gate.sh"
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

pass=0
fail=0

# run NAME EXPECTED_EXIT MUST_CONTAIN [MUST_NOT_CONTAIN]
#   Runs the gate against $TMP/base.json + $TMP/cur.json with the
#   synthetic schema, captures combined output, checks the exit code and
#   (optionally) a required / forbidden substring.
run() {
    local name="$1" want_exit="$2" must="$3" must_not="${4:-}"
    local out got_exit=0
    out="$(BENCH_GATE_BASELINE="$TMP/base.json" \
           BENCH_GATE_CURRENT="$TMP/cur.json" \
           BENCH_GATE_REQUIRED="bench,provenance" \
           "$GATE" 2>&1)" || got_exit=$?
    local ok=1
    if [ "$got_exit" != "$want_exit" ]; then
        echo "FAIL $name: exit $got_exit, wanted $want_exit"
        ok=0
    fi
    if [ -n "$must" ] && ! grep -qF -- "$must" <<<"$out"; then
        echo "FAIL $name: output missing '$must'"
        ok=0
    fi
    if [ -n "$must_not" ] && grep -qF -- "$must_not" <<<"$out"; then
        echo "FAIL $name: output must not contain '$must_not'"
        ok=0
    fi
    if [ "$ok" = 1 ]; then
        echo "ok   $name"
        pass=$((pass + 1))
    else
        sed 's/^/     | /' <<<"$out"
        fail=$((fail + 1))
    fi
}

# Fixture helper: one section ("rows") of measurement rows keyed by op.
#   doc FILE PROVENANCE "op=NAME:median=VALUE" ...
doc() {
    local file="$1" prov="$2"
    shift 2
    python3 - "$file" "$prov" "$@" <<'PY'
import json, sys
file, prov = sys.argv[1], sys.argv[2]
rows = []
for spec in sys.argv[3:]:
    row = {}
    for field in spec.split(":"):
        k, v = field.split("=", 1)
        row[k] = None if v == "null" else (float(v) if k == "median" else v)
    rows.append({"op": row["op"], "median_s": row["median"]})
json.dump({"bench": "synthetic", "provenance": prov, "rows": rows}, open(file, "w"))
PY
}

# 1. Clean pass: current within the 15% threshold.
doc "$TMP/base.json" measured "op=build:median=1.0" "op=query:median=0.5"
doc "$TMP/cur.json" measured "op=build:median=1.05" "op=query:median=0.5"
run "within-threshold passes" 0 "bench_gate: OK"

# 2. Regression: >15% slower on one row hard-fails and names the row.
doc "$TMP/cur.json" measured "op=build:median=1.5" "op=query:median=0.5"
run "regression fails" 1 "op=build"

# 3. Advisory mode downgrades the same regression to exit 0.
out_exit=0
out="$(BENCH_GATE_BASELINE="$TMP/base.json" BENCH_GATE_CURRENT="$TMP/cur.json" \
       BENCH_GATE_REQUIRED="bench,provenance" BENCH_GATE_ADVISORY=1 \
       "$GATE" 2>&1)" || out_exit=$?
if [ "$out_exit" = 0 ] && grep -qF "reporting only" <<<"$out"; then
    echo "ok   advisory downgrades regression"
    pass=$((pass + 1))
else
    echo "FAIL advisory downgrades regression (exit $out_exit)"
    sed 's/^/     | /' <<<"$out"
    fail=$((fail + 1))
fi

# 4. Bootstrap baseline: schema check only, exit 0 even vs a "regression".
doc "$TMP/base.json" bootstrap "op=build:median=null"
run "bootstrap baseline is schema-only" 0 "bootstrap placeholder"

# 5. THE BUGFIX: a current row present with null median_s is skipped
#    with an explicit note — and is NOT counted as an absent row.
doc "$TMP/base.json" measured "op=build:median=1.0" "op=query:median=0.5"
doc "$TMP/cur.json" measured "op=build:median=null" "op=query:median=0.5"
run "null current median_s gets an explicit note" 0 \
    "null median_s in current run" \
    "1 baseline row(s) absent from the current run"

# 6. A row genuinely absent from the current run (e.g. --quick) is the
#    other skip bucket, and never claims a null median.
doc "$TMP/cur.json" measured "op=query:median=0.5"
run "absent current row is the absent bucket" 0 \
    "1 baseline row(s) absent from the current run" \
    "null median_s in current run"

# 7. Current run must be measured — a bootstrap current never gates.
doc "$TMP/cur.json" bootstrap "op=build:median=null"
run "non-measured current rejected" 1 "expected 'measured'"

# 8. Missing schema key fails the load step.
doc "$TMP/cur.json" measured "op=build:median=1.0"
python3 - "$TMP/cur.json" <<'PY'
import json, sys
doc = json.load(open(sys.argv[1]))
del doc["bench"]
json.dump(doc, open(sys.argv[1], "w"))
PY
run "missing required key fails" 1 "missing keys"

# 9. Metric-only fields (τ, the forest sweep's SSE columns) are not row
#    identity: a current row whose measured columns moved still compares
#    against its baseline row instead of being skipped as absent.
python3 - "$TMP/base.json" "$TMP/cur.json" <<'PY'
import json, sys
base = {"bench": "synthetic", "provenance": "measured",
        "rows": [{"op": "train", "median_s": 1.0, "tau": 100,
                  "full_median_s": 2.0, "test_sse_full": 10.0,
                  "test_sse_coreset": 10.5, "sse_gap_pct": 5.0}]}
cur = json.loads(json.dumps(base))
cur["rows"][0].update(median_s=1.05, tau=160, sse_gap_pct=2.5)
json.dump(base, open(sys.argv[1], "w"))
json.dump(cur, open(sys.argv[2], "w"))
PY
run "metric fields are not row identity" 0 \
    "compared 1 row(s)" \
    "absent from the current run"

# 10. The bootstrap-placeholder policy still schema-checks: a bootstrap
#     baseline missing a required key fails the load step (this is what
#     keeps a committed placeholder like BENCH_forest.json honest).
doc "$TMP/base.json" bootstrap "op=build:median=null"
doc "$TMP/cur.json" measured "op=build:median=1.0"
python3 - "$TMP/base.json" <<'PY'
import json, sys
doc = json.load(open(sys.argv[1]))
del doc["bench"]
json.dump(doc, open(sys.argv[1], "w"))
PY
run "bootstrap baseline still schema-checked" 1 "missing keys"

# 11. --rebaseline promotes the current file over the baseline.
doc "$TMP/base.json" measured "op=build:median=1.0"
doc "$TMP/cur.json" measured "op=build:median=0.9"
BENCH_GATE_BASELINE="$TMP/base.json" BENCH_GATE_CURRENT="$TMP/cur.json" \
    "$GATE" --rebaseline >/dev/null
if cmp -s "$TMP/base.json" "$TMP/cur.json"; then
    echo "ok   rebaseline promotes current"
    pass=$((pass + 1))
else
    echo "FAIL rebaseline promotes current"
    fail=$((fail + 1))
fi

echo "test_bench_gate: $pass passed, $fail failed"
[ "$fail" = 0 ]
